//! End-to-end driver (deliverable e2e-1): train the mini-ResNet on the
//! synthetic image task, FP32 vs multiplication-free, through the full
//! stack — rust coordinator -> PJRT -> AOT HLO from JAX -> (bit-equivalent
//! of) the Pallas MF-MAC kernels. Logs both loss curves, reports the
//! accuracy delta (the Table 3 quantity) and the analytical energy ratio,
//! and writes CSV curves under reports/.
//!
//! Run: `cargo run --release --example train_cnn [steps]`

use anyhow::{Context, Result};
use mftrain::coordinator::run_variant;
use mftrain::energy;
use mftrain::models;
use mftrain::runtime::Runtime;
use mftrain::util::table::{fnum, Table};

fn main() -> Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()
        .context("steps must be an integer")?
        .unwrap_or(300);
    let rt = Runtime::cpu()?;
    println!("platform {}, steps {steps}", rt.platform());

    let mut curves = String::from("variant,step,train_loss\n");
    let mut t = Table::new(
        "mini-ResNet on the synthetic image task (ImageNet stand-in)",
        &["variant", "final acc (%)", "loss first->last", "steps/s", "wall (s)"],
    );
    let mut accs = Vec::new();
    for variant in ["cnn_fp32", "cnn_mf"] {
        println!("== training {variant} ==");
        let rec = run_variant(&rt, variant, steps, 0.08, 1.5, 0)?;
        for (s, l) in &rec.loss_curve {
            curves.push_str(&format!("{variant},{s},{l}\n"));
        }
        let (first, last) = rec.loss_span().unwrap_or((f32::NAN, f32::NAN));
        t.row(&[
            variant.to_string(),
            format!("{:.2}", rec.final_accuracy * 100.0),
            format!("{first:.3} -> {last:.3}"),
            format!("{:.2}", rec.steps_per_sec),
            format!("{:.1}", rec.wall_secs),
        ]);
        accs.push(rec.final_accuracy);
        println!(
            "   {} steps, {:.1}s, acc {:.2}%",
            rec.steps,
            rec.wall_secs,
            rec.final_accuracy * 100.0
        );
    }
    t.print();

    let delta = (accs[0] - accs[1]) * 100.0;
    println!(
        "\naccuracy degradation FP32 -> MF: {delta:.2} pts (paper Table 3: <1 pt on ImageNet)"
    );

    // the energy claim for this architecture (analytical, per §6)
    let arch = models::mini_resnet(2);
    let ms = energy::methods();
    let fp32 = energy::training_energy_joules(arch.fw_macs(), 64, &ms[0], false).2;
    let ours = energy::training_energy_joules(
        arch.fw_macs(),
        64,
        ms.iter().find(|m| m.name.starts_with("Ours")).unwrap(),
        true,
    )
    .2;
    println!(
        "linear-layer MAC energy/iteration ({}, batch 64): FP32 {} J vs MF {} J ({:.1}% saved)",
        arch.name,
        fnum(fp32),
        fnum(ours),
        (1.0 - ours / fp32) * 100.0
    );

    std::fs::create_dir_all("reports").ok();
    std::fs::write("reports/train_cnn_curves.csv", curves)?;
    println!("curves -> reports/train_cnn_curves.csv");
    Ok(())
}
