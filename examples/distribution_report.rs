//! Distribution telemetry driver (Figures 2, 3, 6 data): trains the MF
//! CNN while probing W/A/G of the canonical layer, prints log2|x|
//! histograms with their ALS-PoTQ fits, and contrasts the weight-mean
//! drift with and without Weight Bias Correction.
//!
//! Run: `cargo run --release --example distribution_report [steps]`

use anyhow::{Context, Result};
use mftrain::config::TrainConfig;
use mftrain::coordinator::Trainer;
use mftrain::runtime::Runtime;
use mftrain::util::table::{fnum, Table};

fn probe_run(rt: &Runtime, variant: &str, steps: u64, every: u64)
    -> Result<mftrain::coordinator::RunRecord>
{
    let mut cfg = TrainConfig {
        variant: variant.to_string(),
        steps,
        probe_every: every,
        eval_every: 0,
        log_every: 0,
        ..TrainConfig::default()
    };
    cfg.lr.base = 0.08;
    cfg.lr.decay_at = vec![steps * 6 / 10];
    Trainer::new(rt, cfg)?.quiet().run()
}

fn main() -> Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()
        .context("steps must be an integer")?
        .unwrap_or(150);
    let every = (steps / 5).max(1);
    let rt = Runtime::cpu()?;

    // Figure 2/6: W/A/G distributions + quantization fits
    let rec = probe_run(&rt, "cnn_mf", steps, every)?;
    let mut t = Table::new(
        "Figure 2/6 — W/A/G distributions (cnn_mf canonical layer)",
        &["step", "tensor", "mean", "std", "beta", "quant MSE",
          "log2 sigma", "log2|x| density (-40..10)"],
    );
    for p in &rec.probes {
        for (name, s) in [("W", &p.w), ("A", &p.a), ("G", &p.g)] {
            t.row(&[
                p.step.to_string(),
                name.to_string(),
                fnum(s.mean),
                fnum(s.std),
                s.beta.to_string(),
                fnum(s.quant_mse),
                s.log2_sigma.map(fnum).unwrap_or_else(|| "-".into()),
                s.log2_hist.sparkline(),
            ]);
        }
    }
    t.note("spiky single-mode log2|x| densities = the paper's 'near-lognormal' observation; \
            beta separates W/A (small negative) from G (strongly negative)");
    t.print();

    // Figure 3: weight-mean drift with vs without WBC
    let rec_nowbc = probe_run(&rt, "cnn_mf_nowbc", steps, every)?;
    let mut t3 = Table::new(
        "Figure 3 — weight-mean drift over training",
        &["step", "mean(W) with WBC", "mean(W) without WBC"],
    );
    for (a, b) in rec.probes.iter().zip(&rec_nowbc.probes) {
        t3.row(&[a.step.to_string(), format!("{:.3e}", a.w.mean), format!("{:.3e}", b.w.mean)]);
    }
    t3.note("WBC keeps the quantizer input centered; the paper's Figure 3 shows the \
             uncorrected mean deviating over steps");
    t3.print();

    let mut csv = String::from("step,tensor,mean,std,beta,quant_mse\n");
    for p in &rec.probes {
        for (n, s) in [("W", &p.w), ("A", &p.a), ("G", &p.g)] {
            csv.push_str(&format!("{},{},{},{},{},{}\n", p.step, n, s.mean, s.std, s.beta,
                                  s.quant_mse));
        }
    }
    std::fs::create_dir_all("reports").ok();
    std::fs::write("reports/fig2_distributions.csv", csv)?;
    println!("CSV -> reports/fig2_distributions.csv");
    Ok(())
}
