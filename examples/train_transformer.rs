//! End-to-end driver (deliverable e2e-2): train the Transformer on the
//! sequence-transduction task (WMT stand-in), FP32 vs multiplication-free,
//! logging the loss curve — the Table 4 comparison at synthetic scale.
//!
//! Run: `cargo run --release --example train_transformer [steps]`

use anyhow::{Context, Result};
use mftrain::coordinator::run_variant;
use mftrain::runtime::Runtime;
use mftrain::util::table::Table;

fn main() -> Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()
        .context("steps must be an integer")?
        .unwrap_or(400);
    let rt = Runtime::cpu()?;
    println!("platform {}, steps {steps}", rt.platform());

    let mut curves = String::from("variant,step,train_loss\n");
    let mut t = Table::new(
        "Transformer on the transduction task (WMT En-De stand-in)",
        &["variant", "token acc (%)", "loss first->last", "steps/s"],
    );
    let mut accs = Vec::new();
    for variant in ["transformer_fp32", "transformer_mf"] {
        println!("== training {variant} ==");
        let rec = run_variant(&rt, variant, steps, 0.3, 1.0, 0)?;
        for (s, l) in &rec.loss_curve {
            curves.push_str(&format!("{variant},{s},{l}\n"));
        }
        let (first, last) = rec.loss_span().unwrap_or((f32::NAN, f32::NAN));
        t.row(&[
            variant.to_string(),
            format!("{:.2}", rec.final_accuracy * 100.0),
            format!("{first:.3} -> {last:.3}"),
            format!("{:.2}", rec.steps_per_sec),
        ]);
        accs.push(rec.final_accuracy);
        println!("   acc {:.2}% in {:.1}s", rec.final_accuracy * 100.0, rec.wall_secs);
    }
    t.print();
    println!(
        "\ntoken-accuracy degradation FP32 -> MF: {:.2} pts (paper Table 4: 0.3 BLEU)",
        (accs[0] - accs[1]) * 100.0
    );
    std::fs::create_dir_all("reports").ok();
    std::fs::write("reports/train_transformer_curves.csv", curves)?;
    println!("curves -> reports/train_transformer_curves.csv");
    Ok(())
}
