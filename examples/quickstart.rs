//! Quickstart: the three-layer stack in one file.
//!
//! 1. loads the AOT-compiled ALS-PoTQ quantizer + MF-MAC kernels (lowered
//!    from JAX/Pallas by `make artifacts`) and runs them via PJRT;
//! 2. cross-checks them bit-exactly against the rust-native mirror;
//! 3. prints the energy story of the paper for this one matmul block.
//!
//! Run: `cargo run --release --example quickstart`

use std::path::Path;

use anyhow::{ensure, Context, Result};
use mftrain::energy;
use mftrain::potq;
use mftrain::runtime::{Index, Runtime};
use mftrain::util::prng::Pcg32;
use mftrain::util::table::{fnum, Table};

fn main() -> Result<()> {
    let root = Path::new("artifacts");
    let idx = Index::load(root).context("run `make artifacts` first")?;
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());

    // ---- 1. the AOT quantizer kernel vs the rust mirror -----------------
    let potq5 = idx
        .kernels
        .iter()
        .find(|k| k.name == "potq_b5")
        .context("potq_b5 kernel artifact missing")?;
    let exe = rt.compile_file(&root.join(&potq5.file))?;

    let mut rng = Pcg32::new(42);
    let n = potq5.n;
    let mut x = vec![0f32; n];
    rng.fill_normal(&mut x, 0.0, 3.2e-4); // gradient-scale data

    // artifact output layout: [deq | e | s | beta]
    let out = rt.run_f32(&exe, &[(&x, &[n])])?;
    ensure!(out.len() == 3 * n + 1, "unexpected potq output length");
    let (deq_x, rest) = out.split_at(n);
    let (e_x, rest) = rest.split_at(n);
    let (s_x, beta_x) = rest.split_at(n);

    let blk = potq::pot_quantize(&x, 5, None);
    ensure!(blk.beta == beta_x[0] as i32, "beta mismatch");
    let mut exact = 0usize;
    for i in 0..n {
        ensure!(e_x[i] as i32 == blk.e[i], "exponent mismatch at {i}");
        ensure!(s_x[i] as u8 == blk.s[i], "sign mismatch at {i}");
        if deq_x[i].to_bits() == potq::pot_dequantize(blk.e[i], blk.s[i], blk.beta).to_bits() {
            exact += 1;
        }
    }
    ensure!(exact == n, "dequantized values not bit-exact: {exact}/{n}");
    println!(
        "[1] ALS-PoTQ: JAX-lowered kernel == rust mirror, bit-exact on {n} values \
         (beta = {}, zero fraction {:.1}%)",
        blk.beta,
        blk.e.iter().filter(|&&e| e == potq::ZERO_CODE).count() as f64 / n as f64 * 100.0
    );

    // ---- 2. MF-MAC matmul: Pallas kernel vs rust mirror ------------------
    let d = 64usize;
    let mut a = vec![0f32; d * d];
    let mut w = vec![0f32; d * d];
    rng.fill_normal(&mut a, 0.0, 0.5);
    rng.fill_normal(&mut w, 0.0, 0.02);

    for kernel in ["mfmac_ref", "mfmac_pallas", "mfmac_mxu_pallas"] {
        let k = idx
            .kernels
            .iter()
            .find(|k| k.name == kernel)
            .with_context(|| format!("{kernel} artifact missing"))?;
        let exe = rt.compile_file(&root.join(&k.file))?;
        let y = rt.run_f32(&exe, &[(&a, &[d, d]), (&w, &[d, d])])?;
        let y_native = potq::mfmac_matmul(&a, &w, d, d, d, 5);
        let denom = y_native.iter().fold(1e-30f32, |m, &v| m.max(v.abs()));
        let max_rel = y
            .iter()
            .zip(&y_native)
            .map(|(p, q)| (p - q).abs() / denom)
            .fold(0f32, f32::max);
        ensure!(max_rel < 1e-5, "{kernel}: max rel err {max_rel}");
        println!("[2] MF-MAC ({kernel}): PJRT result matches rust mirror (rel err {max_rel:.1e})");
    }

    // ---- 3. the energy story for this block ------------------------------
    let macs = (d * d * d) as f64;
    let mut t = Table::new(
        &format!("energy of one {d}x{d}x{d} matmul block (pJ)"),
        &["MAC realization", "per MAC (pJ)", "block (nJ)", "vs FP32"],
    );
    let fp32 = energy::fp32_mac().energy_pj();
    for (name, pj) in [
        ("FP32 Mul + FP32 Add", fp32),
        ("MF-MAC (INT4 Add + XOR + INT32 Acc)", energy::mf_mac().energy_pj()),
        (
            "MF-MAC + ALS-PoTQ overhead",
            energy::mf_mac().energy_pj() + energy::ALS_POTQ_OVERHEAD_PJ,
        ),
    ] {
        t.row(&[
            name.to_string(),
            fnum(pj),
            fnum(pj * macs * 1e-3),
            format!("{:.1}%", pj / fp32 * 100.0),
        ]);
    }
    t.print();
    println!(
        "headline (§6): {:.1}% of linear-layer training energy removed",
        energy::report::headline_reduction() * 100.0
    );
    println!("\nquickstart OK");
    Ok(())
}
