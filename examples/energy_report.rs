//! Full energy report: Table 1, Table 2 for every evaluation network, the
//! Figure 1 joint series, and the Appendix B overhead accounting. Writes
//! CSVs under reports/.
//!
//! Run: `cargo run --release --example energy_report`

use anyhow::Result;
use mftrain::energy::{self, figure1_series};
use mftrain::models;
use mftrain::util::table::{fnum, Table};

fn main() -> Result<()> {
    energy::table1().print();

    for (model, batch) in [("resnet50", 256u64), ("resnet18", 256), ("alexnet", 256),
                           ("resnet101", 256), ("transformer_base", 128)] {
        let arch = models::by_name(model).unwrap();
        energy::table2(&arch, batch).print();
    }

    // Figure 1: energy vs accuracy
    let arch = models::resnet50();
    let mut t = Table::new(
        "Figure 1 — energy-accuracy joint comparison (ResNet50 @ 256)",
        &["method", "training energy (J/iter)", "ImageNet top-1 (%)", "trains from scratch"],
    );
    let mut csv = String::from("method,energy_j,accuracy,from_scratch\n");
    for p in figure1_series(&arch, 256) {
        t.row(&[
            p.method.clone(),
            fnum(p.energy_j),
            p.accuracy.map(|a| format!("{a:.2}")).unwrap_or_else(|| "-".into()),
            if p.from_scratch { "yes" } else { "no" }.to_string(),
        ]);
        csv.push_str(&format!(
            "{},{},{},{}\n",
            p.method,
            p.energy_j,
            p.accuracy.unwrap_or(f64::NAN),
            p.from_scratch
        ));
    }
    t.note("accuracy values are the paper's Table 3 (literature numbers); energies computed from op mixes");
    t.print();

    // Appendix B: overhead accounting
    let mf = energy::mf_mac().energy_pj();
    println!("\nAppendix B — ALS-PoTQ overhead accounting:");
    println!("  MF-MAC core:            {:.3} pJ/MAC", mf);
    println!("  + scaling INT8 add, rounding carry, amortized INT32 shift: {:.3} pJ",
             energy::ALS_POTQ_OVERHEAD_PJ);
    println!("  = {:.3} pJ/MAC (paper: ~0.195)", mf + energy::ALS_POTQ_OVERHEAD_PJ);
    println!(
        "  headline reduction vs FP32 MAC: {:.1}% (paper: 95.8%)",
        energy::report::headline_reduction() * 100.0
    );

    std::fs::create_dir_all("reports").ok();
    std::fs::write("reports/fig1_energy_accuracy.csv", csv)?;
    std::fs::write("reports/table2_resnet50.csv", energy::table2(&arch, 256).to_csv())?;
    println!("\nCSV -> reports/fig1_energy_accuracy.csv, reports/table2_resnet50.csv");
    Ok(())
}
