"""Scheme-level training behaviour: the L2 facts the paper's tables rest
on, checked at pytest scale (small models, few steps)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import train
from compile.models import mlp


def _task_batch(rng, n=32):
    cls = rng.integers(0, 10, n)
    pat = np.stack([np.sin(np.arange(768) * 0.01 * (c + 1)) for c in cls])
    x = (pat + rng.standard_normal((n, 768)) * 0.8).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(cls.astype(np.int32))


def _run(scheme, steps=40, seed=0, use_pallas=False, batch=32):
    b = train.build(f"s_{scheme}_{use_pallas}", "mlp", mlp.Cfg(), scheme,
                    batch, use_pallas=use_pallas)
    state = b.fns["init"](jnp.int32(seed))
    step = jax.jit(b.fns["train"])
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        x, y = _task_batch(rng, batch)
        state = step(state, x, y, jnp.float32(0.05))
    return b, state


@pytest.mark.parametrize("scheme", ["mf", "luq4", "fp8", "int8", "wpot5"])
def test_all_quant_schemes_learn(scheme):
    b, state = _run(scheme)
    loss = float(b.fns["slice"](state)[0])
    assert loss < 1.0, f"{scheme} failed to learn: {loss}"


def test_mf_close_to_fp32():
    # the Table 3/4 headline at pytest scale: MF within a small margin
    rng = np.random.default_rng(99)
    xe, ye = _task_batch(rng, 64)
    accs = {}
    for scheme in ["fp32", "mf"]:
        b, state = _run(scheme, steps=60)
        m = np.asarray(b.fns["eval"](state, xe[:32], ye[:32]))
        accs[scheme] = m[1] / 32
    assert accs["mf"] >= accs["fp32"] - 0.1, accs


def test_pallas_variant_trains_like_jnp_variant():
    # bit-equivalent kernels => near-identical trajectories
    b1, s1 = _run("mf", steps=12, use_pallas=False, batch=16)
    b2, s2 = _run("mf", steps=12, use_pallas=True, batch=16)
    l1 = float(b1.fns["slice"](s1)[0])
    l2 = float(b2.fns["slice"](s2)[0])
    assert abs(l1 - l2) <= 0.05 * max(abs(l1), 0.05), (l1, l2)


def test_pallas_forward_matches_jnp_forward_exactly_on_first_step():
    # before any divergence accumulates, one step must match very closely
    b1, _ = _run("mf", steps=0, use_pallas=False, batch=8)
    b2, _ = _run("mf", steps=0, use_pallas=True, batch=8)
    state = b1.fns["init"](jnp.int32(5))
    rng = np.random.default_rng(5)
    x, y = _task_batch(rng, 8)
    out1 = np.asarray(b1.fns["train"](state, x, y, jnp.float32(0.05)))
    out2 = np.asarray(b2.fns["train"](state, x, y, jnp.float32(0.05)))
    denom = np.abs(out1).max()
    assert np.abs(out1 - out2).max() / denom < 1e-5


def test_gamma_is_trained():
    b, state = _run("mf", steps=50)
    man = b.manifest
    arr = np.asarray(state)
    gammas = [
        arr[e["offset"]]
        for e in man["layout"]
        if e["path"].startswith("p/") and e["path"].endswith("gamma")
    ]
    assert gammas, "mf scheme must have gamma parameters"
    moved = [g for g in gammas if abs(g - 0.9) > 1e-6]
    assert moved, f"gamma never updated: {gammas}"
    assert all(0.0 < g <= 2.0 for g in gammas), gammas


def test_kernel_report_estimates():
    from compile.kernels import report

    rows = report.estimates()
    assert len(rows) >= 6
    assert all(r.vmem_util < 0.5 for r in rows)
    mxu128 = next(r for r in rows if r.name == "mfmac_mxu tile=128")
    log128 = next(r for r in rows if r.name == "mfmac_logdomain tile=128")
    # log-domain operands are int8-packed -> smaller than f32 operands
    assert log128.vmem_bytes < mxu128.vmem_bytes
