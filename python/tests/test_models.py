"""Model zoo shape/init/tap tests."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import quant
from compile.models import cnn, mlp, transformer

MF = quant.get_scheme("mf")
FP = quant.get_scheme("fp32")


def _leaves_count(tree):
    return sum(int(l.size) for l in jax.tree_util.tree_leaves(tree))


@pytest.mark.parametrize("scheme", [FP, MF])
def test_mlp_shapes(scheme):
    cfg = mlp.Cfg()
    p, s = mlp.init(jax.random.PRNGKey(0), cfg, scheme)
    x = jnp.zeros((4, cfg.in_dim), jnp.float32)
    logits, s2, aux = mlp.apply(p, s, x, scheme, True)
    assert logits.shape == (4, cfg.classes)
    assert aux["tap_a"].shape == mlp.tap_shape(cfg, 4)


@pytest.mark.parametrize("scheme", [FP, MF])
def test_cnn_shapes(scheme):
    cfg = cnn.Cfg(size=16, width=8, blocks=2)
    p, s = cnn.init(jax.random.PRNGKey(0), cfg, scheme)
    x = jnp.zeros((2, 16, 16, 3), jnp.float32)
    logits, s2, aux = cnn.apply(p, s, x, scheme, True)
    assert logits.shape == (2, cfg.classes)
    assert aux["tap_a"].shape == cnn.tap_shape(cfg, 2)
    assert set(s2) == set(s)


def test_cnn_depth_scales_params():
    c2 = cnn.Cfg(blocks=2)
    c3 = cnn.Cfg(blocks=3)
    p2, _ = cnn.init(jax.random.PRNGKey(0), c2, FP)
    p3, _ = cnn.init(jax.random.PRNGKey(0), c3, FP)
    assert _leaves_count(p3) > _leaves_count(p2) * 1.3


@pytest.mark.parametrize("scheme", [FP, MF])
def test_transformer_shapes(scheme):
    cfg = transformer.Cfg()
    p, s = transformer.init(jax.random.PRNGKey(0), cfg, scheme)
    x = jnp.zeros((2, cfg.seq), jnp.int32)
    logits, _, aux = transformer.apply(p, s, x, scheme, True)
    assert logits.shape == (2, cfg.seq, cfg.vocab)
    assert aux["tap_a"].shape == transformer.tap_shape(cfg, 2)


def test_init_deterministic():
    cfg = cnn.Cfg()
    p1, _ = cnn.init(jax.random.PRNGKey(7), cfg, MF)
    p2, _ = cnn.init(jax.random.PRNGKey(7), cfg, MF)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_tap_z_injection_is_additive():
    cfg = mlp.Cfg()
    p, s = mlp.init(jax.random.PRNGKey(0), cfg, FP)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (3, cfg.in_dim)).astype(np.float32))
    z = jnp.zeros(mlp.tap_shape(cfg, 3), jnp.float32)
    l0, _, _ = mlp.apply(p, s, x, FP, True)
    l1, _, _ = mlp.apply(p, s, x, FP, True, tap_z=z)
    assert np.allclose(np.asarray(l0), np.asarray(l1))


def test_loss_and_correct_counts():
    logits = jnp.asarray(np.eye(4, dtype=np.float32) * 10)
    y = jnp.asarray(np.asarray([0, 1, 2, 0], np.int32))
    sum_ce, correct, n = mlp.loss_and_correct(logits, y)
    assert n == 4 and int(correct) == 3


def test_transformer_token_correct_counts():
    b, s, v = 2, 8, 16
    logits = jnp.zeros((b, s, v), jnp.float32).at[..., 3].set(10.0)
    y = jnp.full((b, s), 3, jnp.int32).at[0, 0].set(5)
    sum_ce, correct, n = transformer.loss_and_correct(logits, y)
    assert n == b * s and int(correct) == b * s - 1
