"""Pallas kernels vs the pure-jnp oracle (the CORE L1 correctness signal)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import quant
from compile.kernels import mfmac, potq, ref


def _rand(shape, scale=1.0, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(np.float32)


@pytest.mark.parametrize("b", [3, 4, 5, 6])
@pytest.mark.parametrize("shape", [(8, 8), (256, 16), (512, 32), (100, 7)])
def test_potq_pallas_matches_ref_exactly(b, shape):
    x = _rand(shape, scale=0.03, seed=b)
    e0, s0, b0, d0 = ref.ref_potq(jnp.asarray(x), b)
    e1, s1, b1, d1 = potq.potq_pallas(jnp.asarray(x), b)
    assert int(b0) == int(b1)
    assert np.array_equal(np.asarray(e0), np.asarray(e1))
    assert np.array_equal(np.asarray(s0), np.asarray(s1))
    assert np.array_equal(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("dims", [(8, 8, 8), (64, 64, 64), (128, 64, 32), (65, 33, 17)])
def test_mfmac_pallas_matches_ref(dims):
    m, k, n = dims
    x = _rand((m, k), scale=0.4, seed=m)
    w = _rand((k, n), scale=0.04, seed=n)
    y_ref = np.asarray(ref.ref_mfmac(jnp.asarray(x), jnp.asarray(w)))
    for fn in (mfmac.mfmac_pallas, mfmac.mfmac_mxu_pallas):
        y = np.asarray(fn(jnp.asarray(x), jnp.asarray(w)))
        denom = np.abs(y_ref).max() + 1e-30
        assert np.abs(y - y_ref).max() / denom < 1e-6, fn.__name__


def test_mfmac_logdomain_equals_matmul_form():
    x = _rand((32, 48), scale=2.0, seed=1)
    w = _rand((48, 24), scale=1e-3, seed=2)
    a = np.asarray(ref.ref_mfmac(jnp.asarray(x), jnp.asarray(w)))
    b = np.asarray(ref.ref_mfmac_logdomain(jnp.asarray(x), jnp.asarray(w)))
    assert np.allclose(a, b, rtol=1e-6, atol=1e-30)


def test_mfmac_zero_operand():
    x = jnp.zeros((16, 16), jnp.float32)
    w = jnp.asarray(_rand((16, 16), seed=3))
    assert np.all(np.asarray(mfmac.mfmac_pallas(x, w)) == 0)


def test_mfmac_identityish():
    # w = exact powers of two survive quantization; x PoT too -> exact dot
    x = jnp.asarray(np.diag([2.0, 0.5, 1.0, 4.0]).astype(np.float32))
    w = jnp.asarray((np.ones((4, 4)) * 0.25).astype(np.float32))
    y = np.asarray(mfmac.mfmac_pallas(x, w))
    expect = np.asarray(x) @ np.asarray(w)
    assert np.array_equal(y, expect)


@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([1, 4, 16, 64]),
    k=st.sampled_from([8, 64, 128]),
    n=st.sampled_from([1, 8, 64]),
    sx=st.integers(-12, 6),
    sw=st.integers(-12, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_mfmac_pallas_vs_ref(m, k, n, sx, sw, seed):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((m, k)) * 2.0**sx).astype(np.float32)
    w = (rng.standard_normal((k, n)) * 2.0**sw).astype(np.float32)
    y_ref = np.asarray(ref.ref_mfmac(jnp.asarray(x), jnp.asarray(w)))
    y = np.asarray(mfmac.mfmac_pallas(jnp.asarray(x), jnp.asarray(w)))
    denom = np.abs(y_ref).max() + 1e-30
    assert np.abs(y - y_ref).max() / denom < 1e-5


@settings(max_examples=40, deadline=None)
@given(
    rows=st.integers(1, 520),
    cols=st.integers(1, 9),
    scale_log=st.integers(-20, 10),
    b=st.sampled_from([4, 5]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_potq_pallas_vs_ref(rows, cols, scale_log, b, seed):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((rows, cols)) * 2.0**scale_log).astype(np.float32)
    e0, s0, b0, d0 = ref.ref_potq(jnp.asarray(x), b)
    e1, s1, b1, d1 = potq.potq_pallas(jnp.asarray(x), b)
    assert int(b0) == int(b1)
    assert np.array_equal(np.asarray(e0), np.asarray(e1))
    assert np.array_equal(np.asarray(d0), np.asarray(d1))


def test_quantization_error_vs_bits_monotone():
    # Figure 4's point: more exponent bits only helps near zero; overall
    # MSE after adaptive scaling should be non-increasing in b.
    x = _rand((8192,), seed=9)
    errs = []
    for b in (3, 4, 5, 6):
        d = np.asarray(quant.pot_value(jnp.asarray(x), b))
        errs.append(float(np.mean((d - x) ** 2)))
    assert errs[0] >= errs[1] >= errs[2] >= errs[3]
