"""WBC / PRC / STE / grad_quant / baseline-format unit tests."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import quant


def _rand(shape, scale=1.0, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(np.float32)


def test_wbc_zero_mean():
    w = jnp.asarray(_rand((64, 64), seed=0) + 0.3)
    wc = quant.weight_bias_correction(w)
    assert abs(float(jnp.mean(wc))) < 1e-6


def test_ratio_clip_values():
    a = jnp.asarray(np.linspace(-2, 2, 101).astype(np.float32))
    out = np.asarray(quant.ratio_clip(a, jnp.float32(0.5)))
    assert out.max() == pytest.approx(1.0)  # 0.5 * max|a| = 1
    assert out.min() == pytest.approx(-1.0)
    mid = np.abs(np.asarray(a)) < 1.0
    assert np.array_equal(out[mid], np.asarray(a)[mid])


def test_ratio_clip_gamma_gradient():
    # PACT-style: raising gamma increases clipped outputs, so for a loss
    # that wants larger outputs the gamma gradient must be negative.
    a = jnp.asarray(np.asarray([0.1, 2.0, -2.0, 1.0], np.float32))

    def loss(g):
        return jnp.sum(quant.ratio_clip(a, g))

    g = jax.grad(loss)(jnp.float32(0.25))
    # t = 0.25*2 = 0.5: elements 2.0 and 1.0 clip at +t (+max each), -2.0
    # clips at -t (-max), 0.1 is inside -> total +max = +2
    assert float(g) == pytest.approx(2.0)

    def loss2(g):
        return jnp.sum(quant.ratio_clip(a, g)[1])  # only the +2.0 element

    assert float(jax.grad(loss2)(jnp.float32(0.25))) == pytest.approx(2.0)


def test_ste_identity_gradient():
    x = jnp.asarray(_rand((32,), seed=1))
    g = jax.grad(lambda v: jnp.sum(quant.ste(v, ("pot", 5))))(x)
    assert np.allclose(np.asarray(g), 1.0)


def test_ste_forward_quantized():
    x = jnp.asarray(_rand((32,), seed=2))
    y = np.asarray(quant.ste(x, ("pot", 5)))
    d = np.asarray(quant.pot_value(x, 5))
    assert np.array_equal(y, d)


def test_grad_quant_identity_forward():
    x = jnp.asarray(_rand((16,), seed=3))
    assert np.array_equal(np.asarray(quant.grad_quant(x, ("pot", 5), True)),
                          np.asarray(x))


def test_grad_quant_quantizes_cotangent():
    x = jnp.asarray(_rand((64,), seed=4))
    cot = jnp.asarray(_rand((64,), scale=1e-4, seed=5))

    def f(v):
        return jnp.vdot(quant.grad_quant(v, ("pot", 5), True), cot)

    g = np.asarray(jax.grad(f)(x))
    expect = np.asarray(quant.pot_value(cot, 5))
    assert np.array_equal(g, expect)


def test_grad_quant_respects_6bit_last_layer():
    cot = jnp.asarray(_rand((64,), scale=1e-4, seed=6))
    x = jnp.zeros((64,), jnp.float32)

    def f(v, fmt):
        return jnp.vdot(quant.grad_quant(v, fmt, True), cot)

    g5 = np.asarray(jax.grad(lambda v: f(v, ("pot", 5)))(x))
    g6 = np.asarray(jax.grad(lambda v: f(v, ("pot", 6)))(x))
    # 6-bit keeps strictly more non-zeros (wider exponent range)
    assert (g6 != 0).sum() >= (g5 != 0).sum()


def test_int_value_levels():
    x = jnp.asarray(_rand((512,), seed=7))
    d = np.asarray(quant.int_value(x, 4))
    scale = np.abs(np.asarray(x)).max() / 7
    q = d / scale
    assert np.allclose(q, np.round(q), atol=1e-4)
    assert np.abs(q).max() <= 7 + 1e-4


def test_fp8_value_coarse_but_close():
    x = jnp.asarray(_rand((512,), seed=8))
    d = np.asarray(quant.fp8_value(x))
    # S2FP8 shift keeps everything except the deep sub-window tail; check
    # relative error on values above the shifted flush threshold
    xa = np.abs(np.asarray(x))
    live = xa > xa.max() * 2.0**-13
    rel = np.abs(d - np.asarray(x))[live] / xa[live]
    assert rel.max() < 0.08  # e4m3: ~2^-4 max relative step
    assert not np.array_equal(d, np.asarray(x))


def test_fp8_shift_covers_any_scale():
    # the S2FP8 point: plain e4m3 would clamp at 448 / flush below 2^-6;
    # the shifted format tracks the tensor's own window at any scale
    for scale in [1000.0, 1e-5]:
        x = jnp.asarray(np.asarray([scale, -scale, scale / 4], np.float32))
        d = np.asarray(quant.fp8_value(x))
        rel = np.abs(d - np.asarray(x)) / np.abs(np.asarray(x))
        assert rel.max() < 0.07, (scale, d)


def test_scheme_registry():
    mf = quant.get_scheme("mf")
    assert mf.w == ("pot", 5) and mf.g_last == ("pot", 6)
    assert mf.wbc and mf.prc and mf.als
    assert not quant.get_scheme("fp32").quantized
    with pytest.raises(KeyError):
        quant.get_scheme("nope")


def test_noals_disables_scaling():
    # without ALS, small-magnitude blocks underflow to all-zero (the
    # Table 5 "training collapses" mechanism)
    g = jnp.asarray(_rand((256,), scale=1e-4, seed=9))
    d = np.asarray(quant.pot_value(g, 5, als=False))
    assert np.all(d == 0)
    d_als = np.asarray(quant.pot_value(g, 5, als=True))
    assert (d_als != 0).mean() > 0.9
