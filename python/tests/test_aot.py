"""AOT lowering: HLO text well-formedness and manifest consistency."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, train
from compile.models import mlp


@pytest.fixture(scope="module")
def built():
    return train.build("t_aot_mlp", "mlp", mlp.Cfg(), "mf", 8)


def test_hlo_text_lowering(built):
    lowered = jax.jit(built.fns["slice"]).lower(*built.example_args["slice"])
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    assert f"f32[{built.manifest['state_len']}]" in text
    assert "f32[2]" in text  # output


def test_train_step_signature(built):
    lowered = jax.jit(built.fns["train"]).lower(*built.example_args["train"])
    text = aot.to_hlo_text(lowered)
    s = built.manifest["state_len"]
    # state in, state out, x, y, lr all present in the entry layout
    assert text.count(f"f32[{s}]") >= 2
    assert "s32[8]" in text  # labels
    head = text.split("\n", 1)[0]
    assert "entry_computation_layout" in head


def test_variant_matrix_names_unique():
    names = [v[0] for v in aot.VARIANTS]
    assert len(names) == len(set(names))
    # every scheme referenced exists
    from compile.quant import SCHEMES
    for (_, _, _, scheme, _, _) in aot.VARIANTS:
        assert scheme in SCHEMES


def test_lower_variant_writes_files(tmp_path, built):
    man = aot.lower_variant(built, str(tmp_path))
    vdir = tmp_path / built.name
    for key, fname in man["artifacts"].items():
        p = vdir / fname
        assert p.exists() and p.stat().st_size > 100, key
    with open(vdir / "manifest.json") as f:
        j = json.load(f)
    assert j["state_len"] == built.manifest["state_len"]
    assert j["artifacts"]["train"] == "train.hlo.txt"


def test_kernel_artifact_potq_packing(tmp_path):
    """The potq micro-artifact packs [deq | e | s | beta] as documented."""
    entries = aot.kernel_artifacts(str(tmp_path))
    potq5 = next(e for e in entries if e["name"] == "potq_b5")
    assert potq5["n"] == aot.POTQ_N
    text = open(tmp_path / "kernels" / "potq_b5.hlo.txt").read()
    assert f"f32[{3 * aot.POTQ_N + 1}]" in text


def test_build_variant_lookup():
    b = aot.build_variant("mlp_mf")
    assert b.scheme.name == "mf" and b.batch == 128
    with pytest.raises(KeyError):
        aot.build_variant("nope")
