"""Training-step construction: layout manifest, loss descent, probes."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import train
from compile.models import mlp


def _task_batch(rng, n=32):
    cls = rng.integers(0, 10, n)
    pat = np.stack([np.sin(np.arange(768) * 0.01 * (c + 1)) for c in cls])
    x = (pat + rng.standard_normal((n, 768)) * 0.5).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(cls.astype(np.int32))


@pytest.fixture(scope="module")
def built():
    return train.build("t_mlp_mf", "mlp", mlp.Cfg(), "mf", 32)


def test_layout_covers_state(built):
    man = built.manifest
    end = 0
    for e in man["layout"]:
        assert e["offset"] == end, "layout must be contiguous"
        end += e["size"]
    assert end == man["state_len"]


def test_layout_offsets_match_ravel(built):
    """Poking a value at a manifest offset lands on the right leaf."""
    man = built.manifest
    state = np.array(built.fns["init"](jnp.int32(3)), copy=True)
    fc0 = next(e for e in man["layout"] if e["path"] == "p/fc0/b")
    state[fc0["offset"]] = 1234.5
    from jax.flatten_util import ravel_pytree
    # rebuild the unravel from a template like train.build does
    import jax as _j
    params0, stats0 = mlp.init(_j.random.PRNGKey(0), built.cfg, built.scheme)
    template = {
        "p": params0,
        "m": _j.tree_util.tree_map(jnp.zeros_like, params0),
        "s": stats0,
        "x": {"loss": jnp.float32(0), "step": jnp.float32(0)},
    }
    _, unravel = ravel_pytree(template)
    tree = unravel(jnp.asarray(state))
    assert float(tree["p"]["fc0"]["b"][0]) == 1234.5


def test_loss_and_step_offsets(built):
    man = built.manifest
    state = built.fns["init"](jnp.int32(0))
    rng = np.random.default_rng(0)
    x, y = _task_batch(rng)
    s1 = built.fns["train"](state, x, y, jnp.float32(0.05))
    arr = np.asarray(s1)
    loss, step = built.fns["slice"](s1)
    assert arr[man["loss_offset"]] == pytest.approx(float(loss))
    assert arr[man["step_offset"]] == 1.0


@pytest.mark.parametrize("scheme", ["fp32", "mf"])
def test_loss_decreases(scheme):
    b = train.build(f"t_mlp_{scheme}", "mlp", mlp.Cfg(), scheme, 32)
    state = b.fns["init"](jnp.int32(0))
    step = jax.jit(b.fns["train"])
    rng = np.random.default_rng(1)
    first = None
    for i in range(50):
        x, y = _task_batch(rng)
        state = step(state, x, y, jnp.float32(0.05))
        if i == 0:
            first = float(b.fns["slice"](state)[0])
    last = float(b.fns["slice"](state)[0])
    assert last < first * 0.5, f"{scheme}: {first} -> {last}"


def test_noals_mechanism():
    """Table 5, column 1 mechanism: with beta pinned at 0 the 5-bit PoT
    range is [2^-7, 2^7]; deep-net-scale gradients (|g| ~ 1e-5) quantize
    to all-zero, starving the update — the collapse the paper reports on
    ImageNet. (Small shallow nets with larger gradients can partially
    survive; see EXPERIMENTS.md for the measured table5 shape.)
    """
    from compile import quant

    b = train.build("t_mlp_noals", "mlp", mlp.Cfg(), "mf_noals", 32)
    state = np.asarray(b.fns["init"](jnp.int32(0)))
    man = b.manifest
    went = next(e for e in man["layout"] if e["path"] == "p/fc0/w")
    w0 = state[went["offset"]:went["offset"] + went["size"]]
    assert np.abs(w0).max() > 0, "sanity: real weights"
    # weights mostly survive (emax=7 covers them) — the collapse driver
    # is the gradients, whose scale the fixed range cannot reach:
    g = (np.random.default_rng(0).standard_normal(4096) * 1e-5).astype(np.float32)
    gq = np.asarray(quant.pot_value(jnp.asarray(g), 5, als=False))
    assert np.all(gq == 0), "deep-net-scale gradients must underflow"
    # while ALS keeps them alive
    gq_als = np.asarray(quant.pot_value(jnp.asarray(g), 5, als=True))
    assert (gq_als != 0).mean() > 0.9


def test_eval_step_counts(built):
    state = built.fns["init"](jnp.int32(0))
    rng = np.random.default_rng(3)
    x, y = _task_batch(rng)
    out = np.asarray(built.fns["eval"](state, x, y))
    assert out.shape == (2,)
    assert 0 <= out[1] <= 32
    assert out[0] > 0


def test_probe_sections(built):
    man = built.manifest["probe"]
    state = built.fns["init"](jnp.int32(0))
    rng = np.random.default_rng(4)
    x, y = _task_batch(rng)
    pr = np.asarray(built.fns["probe"](state, x, y))
    total = man["sections"][-1]["offset"] + man["sections"][-1]["size"]
    assert pr.size == total
    g = pr[man["sections"][2]["offset"]:]
    assert np.abs(g).max() > 0, "gradient probe must be non-trivial"


def test_momentum_and_wd_update_rule():
    """One step from a zero-momentum state: p1 = p0 - lr*(g + wd*p0)."""
    b = train.build("t_mlp_fp32u", "mlp", mlp.Cfg(), "fp32", 8,
                    weight_decay=0.1)
    state = b.fns["init"](jnp.int32(0))
    rng = np.random.default_rng(5)
    x, y = _task_batch(rng, 8)

    # compute the raw gradient by hand through eval of loss
    from jax.flatten_util import ravel_pytree
    params0, stats0 = mlp.init(jax.random.PRNGKey(0), b.cfg, b.scheme)
    template = {
        "p": params0,
        "m": jax.tree_util.tree_map(jnp.zeros_like, params0),
        "s": stats0,
        "x": {"loss": jnp.float32(0), "step": jnp.float32(0)},
    }
    _, unravel = ravel_pytree(template)
    tree = unravel(state)
    from compile.models import mlp as mlpmod
    from compile import layers as _l

    def loss_fn(p):
        logits, _, _ = mlpmod.apply(p, tree["s"], x, b.scheme, True)
        s, _, n = mlpmod.loss_and_correct(logits, y)
        return s / n

    g = jax.grad(loss_fn)(tree["p"])
    lr = 0.01
    s1 = unravel(b.fns["train"](state, x, y, jnp.float32(lr)))
    w0 = np.asarray(tree["p"]["fc0"]["w"])
    gw = np.asarray(g["fc0"]["w"])
    w1_expect = w0 - lr * (gw + 0.1 * w0)
    assert np.allclose(np.asarray(s1["p"]["fc0"]["w"]), w1_expect,
                       rtol=1e-5, atol=1e-7)
    # bias has no weight decay
    b0 = np.asarray(tree["p"]["fc0"]["b"])
    gb = np.asarray(g["fc0"]["b"])
    assert np.allclose(np.asarray(s1["p"]["fc0"]["b"]), b0 - lr * gb,
                       rtol=1e-5, atol=1e-7)
