"""Quantized layer tests, incl. the Algorithm-1 backward equivalence."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import layers, quant
from compile.quant import Scheme


def _rand(shape, scale=1.0, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(np.float32)


PURE = Scheme("pure", w=("pot", 5), a=("pot", 5), g=("pot", 5),
              wbc=False, prc=False, als=True)


def test_qdense_fp32_is_plain_matmul():
    p = {"w": jnp.asarray(_rand((8, 4), seed=0)), "b": jnp.zeros(4)}
    a = jnp.asarray(_rand((3, 8), seed=1))
    y = layers.qdense(p, a, quant.get_scheme("fp32"))
    assert np.allclose(np.asarray(y), np.asarray(a) @ np.asarray(p["w"]))


def test_qdense_forward_uses_quantized_operands():
    p = {"w": jnp.asarray(_rand((16, 8), seed=2)), "b": jnp.zeros(8)}
    a = jnp.asarray(_rand((4, 16), seed=3))
    y = np.asarray(layers.qdense(p, a, PURE))
    wq = quant.pot_value(p["w"], 5)
    aq = quant.pot_value(a, 5)
    assert np.allclose(y, np.asarray(aq @ wq), rtol=1e-6)


def test_algorithm1_backward_dW_and_dA():
    """dW == Aqᵀ @ Gq and dA == Gq @ Wqᵀ (Algorithm 1 lines 13-15)."""
    w = jnp.asarray(_rand((16, 8), seed=4))
    a = jnp.asarray(_rand((4, 16), seed=5))
    cot = jnp.asarray(_rand((4, 8), scale=1e-3, seed=6))
    p = {"w": w, "b": jnp.zeros(8)}

    def f(w_, a_):
        return jnp.vdot(layers.qdense({"w": w_, "b": p["b"]}, a_, PURE), cot)

    dw, da = jax.grad(f, argnums=(0, 1))(w, a)
    gq = np.asarray(quant.pot_value(cot, 5))
    aq = np.asarray(quant.pot_value(a, 5))
    wq = np.asarray(quant.pot_value(w, 5))
    assert np.allclose(np.asarray(dw), aq.T @ gq, rtol=1e-5, atol=1e-12)
    assert np.allclose(np.asarray(da), gq @ wq.T, rtol=1e-5, atol=1e-12)


def test_wbc_jacobian_centers_weight_gradient():
    """With WBC on, dW picks up the centering jacobian (mean removed)."""
    sch = Scheme("wbc", w=("pot", 5), a=None, g=None, wbc=True, als=True)
    w = jnp.asarray(_rand((8, 4), seed=7) + 0.5)
    a = jnp.asarray(_rand((2, 8), seed=8))
    cot = jnp.asarray(_rand((2, 4), seed=9))

    def f(w_):
        return jnp.vdot(layers.qdense({"w": w_, "b": jnp.zeros(4)}, a, sch), cot)

    dw = np.asarray(jax.grad(f)(w))
    raw = np.asarray(a).T @ np.asarray(cot)
    assert np.allclose(dw, raw - raw.mean(), rtol=1e-5)


def test_qconv_shapes_and_fp32_exactness():
    p = {"w": jnp.asarray(_rand((3, 3, 4, 8), seed=10)), "b": jnp.zeros(8)}
    x = jnp.asarray(_rand((2, 9, 9, 4), seed=11))
    y = layers.qconv(p, x, quant.get_scheme("fp32"), stride=2)
    assert y.shape == (2, 5, 5, 8)
    y1 = layers.qconv(p, x, quant.get_scheme("fp32"), stride=1)
    assert y1.shape == (2, 9, 9, 8)


def test_qconv_quantized_matches_manual():
    p = {"w": jnp.asarray(_rand((3, 3, 2, 4), seed=12)), "b": jnp.zeros(4)}
    x = jnp.asarray(_rand((1, 6, 6, 2), seed=13))
    y = np.asarray(layers.qconv(p, x, PURE))
    wq = quant.pot_value(p["w"], 5)
    xq = quant.pot_value(x, 5)
    ref = jax.lax.conv_general_dilated(
        xq, wq, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    assert np.allclose(y, np.asarray(ref), rtol=1e-6)


def test_batchnorm_train_and_eval():
    p, s = layers.bn_init(4)
    x = jnp.asarray(_rand((8, 3, 3, 4), seed=14) * 2 + 1)
    y, ns = layers.batchnorm(p, s, x, train=True)
    assert abs(float(jnp.mean(y))) < 1e-5
    assert float(jnp.std(y)) == pytest.approx(1.0, abs=1e-2)
    # running stats moved toward batch stats
    assert np.all(np.asarray(ns["mean"]) != np.asarray(s["mean"]))
    y2, ns2 = layers.batchnorm(p, ns, x, train=False)
    assert ns2 is ns  # eval does not update


def test_layernorm():
    p = layers.ln_init(16)
    x = jnp.asarray(_rand((4, 16), seed=15) * 3 + 2)
    y = np.asarray(layers.layernorm(p, x))
    assert np.allclose(y.mean(-1), 0, atol=1e-5)
    assert np.allclose(y.std(-1), 1, atol=1e-2)


def test_softmax_xent_matches_manual():
    logits = jnp.asarray(_rand((5, 7), seed=16))
    y = jnp.asarray(np.arange(5, dtype=np.int32) % 7)
    ce = np.asarray(layers.softmax_xent(logits, y))
    l = np.asarray(logits)
    manual = np.log(np.exp(l).sum(-1)) - l[np.arange(5), np.asarray(y)]
    assert np.allclose(ce, manual, rtol=1e-5)


def test_dense_init_untruncated_normal_and_gamma():
    sch = quant.get_scheme("mf")
    p = layers.dense_init(jax.random.PRNGKey(0), 256, 128, sch)
    assert p["w"].shape == (256, 128)
    assert float(p["gamma"]) == pytest.approx(sch.gamma_init)
    # untruncated: expect a few |z| > 3 sigma draws in 32k samples
    z = np.asarray(p["w"]) / np.sqrt(2.0 / 256)
    assert (np.abs(z) > 3).sum() > 5
    p32 = layers.dense_init(jax.random.PRNGKey(0), 8, 4, quant.get_scheme("fp32"))
    assert "gamma" not in p32
