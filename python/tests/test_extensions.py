"""Extension formats: unbiased stochastic PoT rounding, per-channel ALS,
and the bit-width-sweep schemes."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import quant


def test_unbiased_rounding_is_unbiased():
    # E[q(x)] over many keys must approach x for values strictly inside
    # the representable range (the top level clamps — a property of the
    # format itself, shared with deterministic rounding)
    x = jnp.asarray(np.asarray([0.3, 0.7, 1.3, -0.9, 0.013, -2.7], np.float32))
    beta = int(quant.compute_beta(x, 5))
    top = 2.0 ** (quant.pot_emax(5) + beta)
    interior = np.abs(np.asarray(x)) < top / 2
    total = np.zeros(6, np.float64)
    n = 600
    for k in range(n):
        q = quant.pot_value_unbiased(x, 5, jax.random.PRNGKey(k))
        total += np.asarray(q, np.float64)
    mean = total / n
    rel = np.abs(mean - np.asarray(x)) / np.abs(np.asarray(x))
    assert rel[interior].max() < 0.08, f"bias too large: {mean} vs {np.asarray(x)}"
    # while deterministic rounding is measurably biased on e.g. 0.3
    det = float(quant.pot_value(jnp.asarray([np.float32(0.3), np.float32(2.7)]), 5)[0])
    assert abs(det - 0.3) > abs(mean[0] - 0.3), "SR should beat deterministic bias"


def test_unbiased_rounding_values_are_pot():
    rng = np.random.default_rng(0)
    x = jnp.asarray((rng.standard_normal(512) * 1e-3).astype(np.float32))
    q = np.asarray(quant.pot_value_unbiased(x, 5, jax.random.PRNGKey(1)))
    nz = q[q != 0]
    l2 = np.log2(np.abs(nz))
    assert np.array_equal(l2, np.round(l2))


def test_unbiased_rounding_deterministic_given_key():
    x = jnp.asarray(np.linspace(-1, 1, 64).astype(np.float32))
    a = quant.pot_value_unbiased(x, 5, jax.random.PRNGKey(7))
    b = quant.pot_value_unbiased(x, 5, jax.random.PRNGKey(7))
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_per_channel_beta_adapts_per_column():
    # two columns with wildly different scales: layer-wise ALS kills the
    # small one, per-channel keeps both alive
    rng = np.random.default_rng(1)
    big = rng.standard_normal(256).astype(np.float32)
    small = (rng.standard_normal(256) * 1e-5).astype(np.float32)
    w = jnp.asarray(np.stack([big, small], axis=1))
    lw = np.asarray(quant.pot_value(w, 5))
    pc = np.asarray(quant.pot_value_per_channel(w, 5))
    assert (lw[:, 1] == 0).mean() > 0.9, "layer-wise underflows the small column"
    assert (pc[:, 1] != 0).mean() > 0.9, "per-channel keeps it alive"
    # per-channel values are still PoT
    nz = pc[pc != 0]
    l2 = np.log2(np.abs(nz))
    assert np.array_equal(l2, np.round(l2))


def test_per_channel_matches_layerwise_on_uniform_scales():
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.standard_normal((64, 8)).astype(np.float32))
    # columns share the scale: per-channel betas may differ by <=1 from
    # the layer-wise beta, so values agree within a factor of 2 on a
    # near-max element; weaker but meaningful: both keep everything alive
    lw = np.asarray(quant.pot_value(w, 5))
    pc = np.asarray(quant.pot_value_per_channel(w, 5))
    assert (lw != 0).mean() > 0.95
    assert (pc != 0).mean() > 0.95


@settings(max_examples=30, deadline=None)
@given(cols=st.integers(1, 6), rows=st.integers(1, 100),
       seed=st.integers(0, 2**31 - 1))
def test_hypothesis_per_channel_pot(cols, rows, seed):
    rng = np.random.default_rng(seed)
    scales = 2.0 ** rng.integers(-15, 5, cols)
    w = (rng.standard_normal((rows, cols)) * scales).astype(np.float32)
    pc = np.asarray(quant.pot_value_per_channel(jnp.asarray(w), 5))
    nz = pc[pc != 0]
    if nz.size:
        l2 = np.log2(np.abs(nz))
        assert np.array_equal(l2, np.round(l2))
    # sign preservation
    live = pc != 0
    assert np.array_equal(np.sign(pc[live]), np.sign(w[live]))


def test_sweep_schemes_registered():
    for name in ["mf4", "mf6", "mf_sr", "mf_pc"]:
        s = quant.get_scheme(name)
        assert s.quantized and s.als
    assert quant.get_scheme("mf4").w == ("pot", 4)
    assert quant.get_scheme("mf_sr").g == ("potu", 5)
    assert quant.get_scheme("mf_pc").w == ("potc", 5)


def test_grad_quant_with_potu_runs_in_grad():
    x = jnp.asarray(np.ones(32, np.float32))
    cot = jnp.asarray((np.random.default_rng(3).standard_normal(32) * 1e-4)
                      .astype(np.float32))

    def f(v):
        return jnp.vdot(quant.grad_quant(v, ("potu", 5), True), cot)

    g = np.asarray(jax.grad(f)(x))
    nz = g[g != 0]
    l2 = np.log2(np.abs(nz))
    assert np.array_equal(l2, np.round(l2)), "stochastic-rounded grads are PoT"
