"""ALS-PoTQ quantizer properties (the numeric contract), incl. hypothesis
sweeps. These invariants are mirrored by the rust property tests in
rust/src/potq — keep the two in sync."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import quant

BITS = [3, 4, 5, 6]


def _rand(shape, scale=1.0, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(np.float32)


@pytest.mark.parametrize("b", BITS)
def test_values_are_pot(b):
    x = _rand((64, 32), scale=3e-4, seed=1)
    d = np.asarray(quant.pot_value(jnp.asarray(x), b))
    nz = d[d != 0]
    l2 = np.log2(np.abs(nz))
    assert np.array_equal(l2, np.round(l2)), "dequantized values must be PoT"


@pytest.mark.parametrize("b", BITS)
def test_exponent_range(b):
    emax = quant.pot_emax(b)
    x = _rand((128,), scale=7.3, seed=2)
    e, s, beta = quant.pot_quantize(jnp.asarray(x), b)
    e = np.asarray(e)
    live = e != quant.ZERO_CODE
    assert live.any()
    assert e[live].min() >= -emax and e[live].max() <= emax
    assert set(np.unique(np.asarray(s))) <= {0, 1}


def test_sign_preserved():
    x = _rand((256,), seed=3)
    d = np.asarray(quant.pot_value(jnp.asarray(x), 5))
    nz = d != 0
    assert np.array_equal(np.sign(d[nz]), np.sign(x[nz]))


def test_zero_block():
    x = jnp.zeros((16, 16), jnp.float32)
    e, s, beta = quant.pot_quantize(x, 5)
    assert int(beta) == 0
    assert np.all(np.asarray(e) == quant.ZERO_CODE)
    assert np.all(np.asarray(quant.pot_dequantize(e, s, beta)) == 0)


def test_subnormals_flush_to_zero():
    x = np.asarray([1e-42, -1e-40, 0.0, 1.0], np.float32)  # first two subnormal
    d = np.asarray(quant.pot_value(jnp.asarray(x), 5))
    assert d[0] == 0 and d[1] == 0 and d[2] == 0 and d[3] != 0


def test_max_maps_to_near_emax():
    # after adaptive scaling the max magnitude lands within 1 of emax
    x = _rand((512,), scale=1e-6, seed=4)
    e, s, beta = quant.pot_quantize(jnp.asarray(x), 5)
    amax_e = np.asarray(e)[np.argmax(np.abs(x))]
    assert quant.pot_emax(5) - 1 <= amax_e <= quant.pot_emax(5)


def test_relative_error_bound():
    # PoT rounding in log domain: |f - q| / |f| <= 2^0.5 - 1 for values
    # inside the representable range
    x = np.abs(_rand((4096,), seed=5)) + 0.1
    d = np.asarray(quant.pot_value(jnp.asarray(x), 5))
    live = d != 0
    rel = np.abs(x[live] - d[live]) / np.abs(x[live])
    assert rel.max() <= 2**0.5 - 1 + 1e-6


def test_quantize_idempotent():
    x = _rand((128, 8), seed=6)
    d1 = quant.pot_value(jnp.asarray(x), 5)
    d2 = quant.pot_value(d1, 5)
    assert np.array_equal(np.asarray(d1), np.asarray(d2))


@pytest.mark.parametrize("b", BITS)
def test_beta_formula(b):
    x = _rand((1000,), scale=2.0, seed=7)
    _, _, beta = quant.pot_quantize(jnp.asarray(x), b)
    expect = round(np.log2(np.max(np.abs(x)))) - quant.pot_emax(b)
    assert abs(int(beta) - expect) <= 1  # ties at the sqrt2 boundary


def test_round_log2_boundary_contract():
    # exactly at a power of two: no carry; just below double: carry
    x = np.asarray([1.0, 1.9999999, 2.0, 1.4142134, 1.4142137], np.float32)
    e, is_zero = quant.round_log2_abs(jnp.asarray(x))
    e = np.asarray(e)
    assert e[0] == 0 and e[1] == 1 and e[2] == 1
    assert e[3] == 0 and e[4] == 1  # straddles SQRT2_F32


def test_gradient_scale_range_like_paper():
    # paper: beta in roughly [-20,-10] for G, [-5,-2] for W — sanity-check
    # that tiny-magnitude blocks produce strongly negative betas
    g = _rand((4096,), scale=2e-5, seed=8)
    _, _, beta = quant.pot_quantize(jnp.asarray(g), 5)
    assert -26 <= int(beta) <= -10


@settings(max_examples=60, deadline=None)
@given(
    n=st.integers(1, 300),
    scale_log=st.integers(-30, 20),
    b=st.sampled_from(BITS),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_roundtrip_properties(n, scale_log, b, seed):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal(n) * 2.0**scale_log).astype(np.float32)
    e, s, beta = quant.pot_quantize(jnp.asarray(x), b)
    d = np.asarray(quant.pot_dequantize(e, s, beta))
    e_np = np.asarray(e)
    live = e_np != quant.ZERO_CODE
    emax = quant.pot_emax(b)
    # exponent bounds
    if live.any():
        assert e_np[live].min() >= -emax and e_np[live].max() <= emax
    # sign agreement and a loose relative-error bound on live entries
    if live.any():
        assert np.array_equal(np.sign(d[live]), np.sign(x[live]))
        rel = np.abs(d[live] - x[live]) / np.abs(x[live])
        assert rel.max() <= 0.5
    # anything quantized to zero must be small vs the block scale
    dead = ~live
    if dead.any() and live.any():
        assert np.abs(x[dead]).max() <= 2.0 ** (float(beta) - emax + 1) * 2**emax
