"""AOT compiler: lower every (model x scheme) variant to HLO text.

Run once at build time (``make artifacts``); the rust coordinator is fully
self-contained afterwards. HLO *text* is the interchange format — jax >= 0.5
serialized protos carry 64-bit instruction ids that xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out ../artifacts [--only NAME]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import train
from .kernels import mfmac as mfmac_kernel
from .kernels import ref as kernels_ref
from .models import cnn, mlp, transformer


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Variant matrix (DESIGN.md §Artifact variant matrix)
# ---------------------------------------------------------------------------

MLP = mlp.Cfg()
CNN = cnn.Cfg(size=16, width=8, blocks=2)
CNN_DEEP = cnn.Cfg(size=16, width=8, blocks=3)
TRF = transformer.Cfg(vocab=64, seq=32, d=96, heads=4, ffn=192, depth=2)

#              name                model          cfg       scheme      batch pallas
VARIANTS = [
    ("mlp_fp32", "mlp", MLP, "fp32", 128, False),
    ("mlp_mf", "mlp", MLP, "mf", 128, False),
    ("mlp_mf_pallas", "mlp", MLP, "mf", 128, True),
    ("cnn_fp32", "cnn", CNN, "fp32", 64, False),
    ("cnn_mf", "cnn", CNN, "mf", 64, False),
    ("cnn_mf_nowbc", "cnn", CNN, "mf_nowbc", 64, False),
    ("cnn_mf_noprc", "cnn", CNN, "mf_noprc", 64, False),
    ("cnn_mf_noals", "cnn", CNN, "mf_noals", 64, False),
    ("cnn_wpot5", "cnn", CNN, "wpot5", 64, False),
    ("cnn_wapot4", "cnn", CNN, "wapot4", 64, False),
    ("cnn_luq4", "cnn", CNN, "luq4", 64, False),
    ("cnn_fp8", "cnn", CNN, "fp8", 64, False),
    ("cnn_int8", "cnn", CNN, "int8", 64, False),
    ("cnn_mf4", "cnn", CNN, "mf4", 64, False),
    ("cnn_mf6", "cnn", CNN, "mf6", 64, False),
    ("cnn_mf_sr", "cnn", CNN, "mf_sr", 64, False),
    ("cnn_mf_pc", "cnn", CNN, "mf_pc", 64, False),
    ("cnn_deep_fp32", "cnn_deep", CNN_DEEP, "fp32", 64, False),
    ("cnn_deep_mf", "cnn_deep", CNN_DEEP, "mf", 64, False),
    ("transformer_fp32", "transformer", TRF, "fp32", 32, False),
    ("transformer_mf", "transformer", TRF, "mf", 32, False),
    ("transformer_luq4", "transformer", TRF, "luq4", 32, False),
    ("transformer_fp8", "transformer", TRF, "fp8", 32, False),
]


def lower_variant(built: train.Built, outdir: str) -> dict:
    vdir = os.path.join(outdir, built.name)
    os.makedirs(vdir, exist_ok=True)
    files = {}
    for key, fn in built.fns.items():
        t0 = time.time()
        # donate the state buffer on the train step: PJRT then aliases the
        # output state onto the input allocation (perf pass, L2; the rust
        # session never reuses the input buffer after execute_b)
        donate = (0,) if key == "train" else ()
        lowered = jax.jit(fn, donate_argnums=donate).lower(*built.example_args[key])
        text = to_hlo_text(lowered)
        fname = f"{key}.hlo.txt"
        with open(os.path.join(vdir, fname), "w") as f:
            f.write(text)
        files[key] = fname
        print(f"  {built.name}/{fname}: {len(text)//1024} KiB "
              f"({time.time()-t0:.1f}s)")
    man = dict(built.manifest)
    man["artifacts"] = files
    with open(os.path.join(vdir, "manifest.json"), "w") as f:
        json.dump(man, f, indent=1)
    return man


# ---------------------------------------------------------------------------
# Micro-kernel artifacts: the rust potq/mfmac mirror cross-validates against
# these (bit-exactness contract, DESIGN.md §Numeric contract).
# ---------------------------------------------------------------------------

POTQ_N = 4096
MFMAC_DIM = 64


def kernel_artifacts(outdir: str) -> list:
    kdir = os.path.join(outdir, "kernels")
    os.makedirs(kdir, exist_ok=True)
    sds = jax.ShapeDtypeStruct
    entries = []

    for b in (3, 4, 5, 6):
        def potq_fn(x, b=b):
            e, s, beta, deq = kernels_ref.ref_potq(x, b)
            return jnp.concatenate([
                deq,
                e.astype(jnp.float32),
                s.astype(jnp.float32),
                beta.astype(jnp.float32).reshape(1),
            ])

        name = f"potq_b{b}"
        lowered = jax.jit(potq_fn).lower(sds((POTQ_N,), jnp.float32))
        with open(os.path.join(kdir, f"{name}.hlo.txt"), "w") as f:
            f.write(to_hlo_text(lowered))
        entries.append({
            "name": name, "file": f"kernels/{name}.hlo.txt", "bits": b,
            "n": POTQ_N, "outputs": ["deq", "e", "s", "beta"],
        })
        print(f"  kernels/{name}")

    d = MFMAC_DIM
    for name, fn in [
        ("mfmac_ref", lambda x, w: kernels_ref.ref_mfmac(x, w, 5)),
        ("mfmac_pallas", lambda x, w: mfmac_kernel.mfmac_pallas(x, w, 5)),
        ("mfmac_mxu_pallas", lambda x, w: mfmac_kernel.mfmac_mxu_pallas(x, w, 5)),
    ]:
        lowered = jax.jit(fn).lower(
            sds((d, d), jnp.float32), sds((d, d), jnp.float32))
        with open(os.path.join(kdir, f"{name}.hlo.txt"), "w") as f:
            f.write(to_hlo_text(lowered))
        entries.append({
            "name": name, "file": f"kernels/{name}.hlo.txt", "bits": 5,
            "m": d, "k": d, "n": d,
        })
        print(f"  kernels/{name}")
    return entries


def build_variant(name: str) -> train.Built:
    for (n, model, cfg, scheme, batch, pallas) in VARIANTS:
        if n == name:
            return train.build(n, model, cfg, scheme, batch, use_pallas=pallas)
    raise KeyError(name)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated variant names (default: all)")
    ap.add_argument("--skip-kernels", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    index = {"variants": [], "kernels": []}
    t0 = time.time()
    if not args.skip_kernels:
        index["kernels"] = kernel_artifacts(args.out)
    for (name, model, cfg, scheme, batch, pallas) in VARIANTS:
        if only and name not in only:
            continue
        built = train.build(name, model, cfg, scheme, batch, use_pallas=pallas)
        man = lower_variant(built, args.out)
        index["variants"].append({
            "name": name, "model": model, "scheme": scheme,
            "state_len": man["state_len"], "n_params": man["n_params"],
        })
    with open(os.path.join(args.out, "index.json"), "w") as f:
        json.dump(index, f, indent=1)
    print(f"AOT done in {time.time()-t0:.0f}s -> {args.out}")


if __name__ == "__main__":
    main()
