"""Layer-2 building blocks: quantized linear/conv layers per Algorithm 1.

Every quantized layer performs, for scheme S:
  forward   Wq = ALS-PoTQ(WBC(W)),  Aq = ALS-PoTQ(PRC(A, gamma))
            y  = Aq @ Wq                          (the MF-MAC matmul)
  backward  Gq = ALS-PoTQ(G)  via ``grad_quant`` (identity forward, the
            cotangent is quantized before it reaches the matmul's VJP), so
            dA = Gq @ Wqᵀ and dW = Aqᵀ @ Gq — exactly Algorithm 1 lines
            13-15, since JAX's matmul VJP closes over the *quantized*
            operands saved by the forward pass.
Master weights stay FP32 (straight-through estimator), as in the paper's
training scheme (the FP32 update path is the standard QAT formulation).

When ``use_pallas`` is set on the scheme config the forward matmul lowers
through the L1 Pallas MF-MAC kernel instead of the (bit-equivalent) jnp
path — used by the ``*_pallas`` artifact variants.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import quant
from .quant import Scheme


def quantize_weight(w: jnp.ndarray, scheme: Scheme) -> jnp.ndarray:
    """WBC + format STE for a weight tensor."""
    if scheme.w is None:
        return w
    if scheme.wbc:
        w = quant.weight_bias_correction(w)
    return quant.ste(w, scheme.w, als=scheme.als)


def quantize_act(
    a: jnp.ndarray, gamma: Optional[jnp.ndarray], scheme: Scheme
) -> jnp.ndarray:
    """PRC + format STE for an activation tensor."""
    if scheme.a is None:
        return a
    if scheme.prc and gamma is not None:
        a = quant.ratio_clip(a, gamma)
    return quant.ste(a, scheme.a, als=scheme.als)


def _g_fmt(scheme: Scheme, last: bool):
    if last and scheme.g_last is not None:
        return scheme.g_last
    return scheme.g


from functools import partial


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def _pallas_matmul(aq, wq, b):
    """MF-MAC matmul through the L1 Pallas kernel, with the Algorithm-1
    backward rules attached explicitly (interpret-mode pallas_call has no
    reverse-mode rule of its own). Operands are already PoT values, so the
    kernel's internal re-quantization is the identity."""
    from .kernels import mfmac as mfmac_kernel

    return mfmac_kernel.mfmac_mxu_pallas(aq, wq, b=b)


def _pallas_matmul_fwd(aq, wq, b):
    return _pallas_matmul(aq, wq, b), (aq, wq)


def _pallas_matmul_bwd(b, res, g):
    # g is the (already grad_quant-quantized) G_q: both backward matmuls
    # are themselves MF-MAC computations (Algorithm 1 lines 14-15).
    from .kernels import mfmac as mfmac_kernel

    aq, wq = res
    da = mfmac_kernel.mfmac_mxu_pallas(g, wq.T, b=b)
    dw = mfmac_kernel.mfmac_mxu_pallas(aq.T, g, b=b)
    return da, dw


_pallas_matmul.defvjp(_pallas_matmul_fwd, _pallas_matmul_bwd)


def _maybe_pallas_matmul(aq, wq, scheme: Scheme, use_pallas: bool):
    if use_pallas and scheme.w is not None and scheme.w[0] == "pot":
        return _pallas_matmul(aq, wq, scheme.w[1])
    return jnp.matmul(aq, wq)


def qdense(
    params: Dict[str, jnp.ndarray],
    a: jnp.ndarray,
    scheme: Scheme,
    last: bool = False,
    use_pallas: bool = False,
) -> jnp.ndarray:
    """Quantized fully-connected layer. params: w (in,out), b (out), gamma."""
    wq = quantize_weight(params["w"], scheme)
    aq = quantize_act(a, params.get("gamma"), scheme)
    shape = a.shape
    a2 = aq.reshape(-1, shape[-1])
    y = _maybe_pallas_matmul(a2, wq, scheme, use_pallas)
    y = y.reshape(*shape[:-1], wq.shape[-1])
    if scheme.g is not None:
        y = quant.grad_quant(y, _g_fmt(scheme, last), scheme.als)
    return y + params["b"]


def qconv(
    params: Dict[str, jnp.ndarray],
    a: jnp.ndarray,
    scheme: Scheme,
    stride: int = 1,
    padding: str = "SAME",
) -> jnp.ndarray:
    """Quantized conv2d (NHWC x HWIO). Same Algorithm-1 structure as qdense;
    the conv VJP likewise closes over the quantized operands, and the
    cotangent passes through grad_quant, so dA/dW are MF-MAC computations.
    """
    wq = quantize_weight(params["w"], scheme)
    aq = quantize_act(a, params.get("gamma"), scheme)
    y = lax.conv_general_dilated(
        aq,
        wq,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if scheme.g is not None:
        y = quant.grad_quant(y, _g_fmt(scheme, False), scheme.als)
    return y + params["b"]


# ---------------------------------------------------------------------------
# FP32 helpers (the paper quantizes linear layers only; norms/softmax stay
# full precision, consistent with Table 2 counting MAC energy of linears).
# ---------------------------------------------------------------------------


def batchnorm(
    params: Dict[str, jnp.ndarray],
    stats: Dict[str, jnp.ndarray],
    x: jnp.ndarray,
    train: bool,
    momentum: float = 0.9,
    eps: float = 1e-5,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """BatchNorm over NHWC (axes 0,1,2). Returns (y, new_stats)."""
    if train:
        mean = jnp.mean(x, axis=(0, 1, 2))
        var = jnp.var(x, axis=(0, 1, 2))
        new_stats = {
            "mean": momentum * stats["mean"] + (1 - momentum) * mean,
            "var": momentum * stats["var"] + (1 - momentum) * var,
        }
    else:
        mean, var = stats["mean"], stats["var"]
        new_stats = stats
    y = (x - mean) * lax.rsqrt(var + eps)
    return y * params["scale"] + params["shift"], new_stats


def layernorm(params: Dict[str, jnp.ndarray], x: jnp.ndarray, eps: float = 1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) * lax.rsqrt(var + eps) * params["scale"] + params["shift"]


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Per-example cross-entropy; labels int32, logits (..., C)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return logz - gold


# ---------------------------------------------------------------------------
# Initializers — untruncated normal, as the paper stresses (Section 7.1.1:
# "the initializer of weight should be untruncated normal distribution").
# ---------------------------------------------------------------------------


def he_normal(key, shape, fan_in: int) -> jnp.ndarray:
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


def dense_init(key, n_in: int, n_out: int, scheme: Scheme) -> Dict[str, jnp.ndarray]:
    p = {
        "w": he_normal(key, (n_in, n_out), n_in),
        "b": jnp.zeros((n_out,), jnp.float32),
    }
    if scheme.prc and scheme.a is not None:
        p["gamma"] = jnp.float32(scheme.gamma_init)
    return p


def conv_init(key, kh, kw, cin, cout, scheme: Scheme) -> Dict[str, jnp.ndarray]:
    p = {
        "w": he_normal(key, (kh, kw, cin, cout), kh * kw * cin),
        "b": jnp.zeros((cout,), jnp.float32),
    }
    if scheme.prc and scheme.a is not None:
        p["gamma"] = jnp.float32(scheme.gamma_init)
    return p


def bn_init(c: int):
    params = {"scale": jnp.ones((c,), jnp.float32), "shift": jnp.zeros((c,), jnp.float32)}
    stats = {"mean": jnp.zeros((c,), jnp.float32), "var": jnp.ones((c,), jnp.float32)}
    return params, stats


def ln_init(c: int):
    return {"scale": jnp.ones((c,), jnp.float32), "shift": jnp.zeros((c,), jnp.float32)}
