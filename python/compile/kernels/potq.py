"""Layer-1 Pallas kernel: ALS-PoTQ block quantization.

TPU mapping (DESIGN.md §Hardware-Adaptation): the block lives in VMEM; the
sign/exponent extraction is pure VPU bit work (bitcast + shifts + compares,
8/32-bit lanes); beta is a scalar (SMEM) computed by a max-reduction pass.
``interpret=True`` everywhere — real Mosaic lowering cannot execute on the
CPU PJRT plugin (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .. import quant

# Rows per grid step when tiling large blocks.
_TILE = 256


def _quantize_kernel(beta_ref, x_ref, e_ref, s_ref, deq_ref, *, b: int):
    """Quantize one VMEM tile given the (precomputed) scalar beta."""
    x = x_ref[...]
    beta = beta_ref[0]
    emaxv = quant.pot_emax(b)

    bits = lax.bitcast_convert_type(x, jnp.int32)
    sign = jnp.right_shift(bits, 31) & 1
    biased = jnp.right_shift(bits, 23) & 0xFF
    m23 = bits & 0x7FFFFF
    m = 1.0 + m23.astype(jnp.float32) * jnp.float32(2.0**-23)
    is_zero = biased == 0
    e_real = biased - 127 + (m > quant.SQRT2_F32).astype(jnp.int32)
    e = e_real - beta
    zero = is_zero | (e < -emaxv)
    e = jnp.minimum(e, emaxv)
    e = jnp.where(zero, quant.ZERO_CODE, e)
    s = jnp.where(zero, 0, sign)

    mag_bits = jnp.left_shift(jnp.where(zero, 0, e + beta) + 127, 23)
    mag = lax.bitcast_convert_type(mag_bits, jnp.float32)
    deq = jnp.where(zero, 0.0, jnp.where(s == 1, -mag, mag))

    e_ref[...] = e
    s_ref[...] = s
    deq_ref[...] = deq


def potq_pallas(x: jnp.ndarray, b: int = 5) -> Tuple[jnp.ndarray, ...]:
    """ALS-PoTQ of a 2-D block via Pallas: (e, s, beta, deq).

    beta is computed with a jnp max first (a layer-wise scalar — one per
    tens of thousands of elements, exactly the cost the paper argues is
    negligible); the per-element quantization runs as a tiled Pallas kernel.
    """
    assert x.ndim == 2, "potq_pallas operates on 2-D blocks"
    beta = quant.compute_beta(x, b)
    m, n = x.shape
    tile = _TILE if m % _TILE == 0 else m
    grid = (m // tile,)
    e, s, deq = pl.pallas_call(
        functools.partial(_quantize_kernel, b=b),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # beta scalar (SMEM on TPU)
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.int32),
            jax.ShapeDtypeStruct((m, n), jnp.int32),
            jax.ShapeDtypeStruct((m, n), jnp.float32),
        ],
        interpret=True,
    )(beta.reshape(1), x)
    return e, s, beta, deq


def vmem_footprint_bytes(m: int, n: int, tile: int = _TILE) -> int:
    """VMEM bytes per grid step for the quantize kernel (perf estimate).

    x tile f32 + e tile i32 + s tile i32 + deq tile f32 = 16 bytes/elem.
    On real TPU e/s would be packed int8/int1 (5.125 B/elem); we report the
    interpret-mode layout here and the packed layout in EXPERIMENTS §Perf.
    """
    t = min(tile, m)
    return 16 * t * n
