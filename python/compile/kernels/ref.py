"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness references: pytest asserts the Pallas
kernels (interpret=True) match these functions, and the rust-native mirror
(rust/src/potq) is cross-checked against the AOT-lowered versions of these
via the ``potq_quantize`` / ``mfmac`` micro-artifacts.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from .. import quant


def ref_potq(x: jnp.ndarray, b: int = 5) -> Tuple[jnp.ndarray, ...]:
    """ALS-PoTQ of a block: (e int32, s int32, beta int32, deq f32)."""
    e, s, beta = quant.pot_quantize(x, b)
    deq = quant.pot_dequantize(e, s, beta)
    return e, s, beta, deq


def ref_mfmac(x: jnp.ndarray, w: jnp.ndarray, b: int = 5) -> jnp.ndarray:
    """MF-MAC matmul semantics: exact dot of the PoT-quantized operands.

    Each product (1-2s)2^(ex+ew) is a signed power of two — exactly what the
    hardware's INT4 exponent add + sign XOR produces; the accumulation here
    is f32 (the INT32 fixed-point accumulator study lives in rust).
    """
    ex, sx, bx = quant.pot_quantize(x, b)
    ew, sw, bw = quant.pot_quantize(w, b)
    xq = quant.pot_dequantize(ex, sx, bx)
    wq = quant.pot_dequantize(ew, sw, bw)
    return jnp.matmul(xq, wq)


def ref_mfmac_logdomain(x: jnp.ndarray, w: jnp.ndarray, b: int = 5) -> jnp.ndarray:
    """Log-domain formulation (what the Pallas kernel implements):

    acc[m,n] = sum_k (1 - 2*(sx^sw)) * 2^(ex[m,k] + ew[k,n]),
    output    = acc * 2^(beta_x + beta_w).

    Mathematically identical to ref_mfmac up to f32 accumulation order.
    """
    ex, sx, bx = quant.pot_quantize(x, b)
    ew, sw, bw = quant.pot_quantize(w, b)
    zx = (ex == quant.ZERO_CODE)[:, :, None]
    zw = (ew == quant.ZERO_CODE)[None, :, :]
    esum = jnp.where(zx | zw, 0, ex[:, :, None] + ew[None, :, :])
    ssum = sx[:, :, None] ^ sw[None, :, :]
    mag = quant.pow2i(esum)
    term = jnp.where(zx | zw, 0.0, jnp.where(ssum == 1, -mag, mag))
    acc = jnp.sum(term, axis=1)
    return acc * quant.pow2i(bx + bw)
