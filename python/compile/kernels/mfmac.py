"""Layer-1 Pallas kernel: MF-MAC matmul in the log domain.

The paper's MF-MAC (Figure 5) replaces each FP32 multiply with
  * an INT4 add of the two PoT exponents       -> ``ex + ew`` below,
  * a 1-bit XOR of the two sign bits           -> ``sx ^ sw``,
  * an INT32 accumulation of the signed 2^e    -> the K-loop accumulator,
  * one scalar shift by beta_x + beta_w        -> final ``* 2^(bx+bw)``.

TPU mapping (DESIGN.md §Hardware-Adaptation): exponent/sign tiles are VMEM
residents (int8/int1-packed on real hardware); the exponent add + XOR is
VPU work; the accumulator is a VMEM scratch tile carried across the K grid
dimension — the Pallas analogue of the paper's per-MAC INT32 register. The
dequantize-then-MXU schedule (what today's TPUs would actually run) is
``mfmac_mxu_pallas`` below.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .. import quant
from . import potq as potq_kernel

# M/N/K tile sizes for the grid (VMEM-sized on real hardware).
_TM, _TN, _TK = 64, 64, 64


def _pow2f(e: jnp.ndarray) -> jnp.ndarray:
    """Exact 2^e from bits for integer e (vector, in-kernel)."""
    return lax.bitcast_convert_type(
        jnp.left_shift(e.astype(jnp.int32) + 127, 23), jnp.float32
    )


def _mfmac_kernel(ex_ref, sx_ref, ew_ref, sw_ref, o_ref, *, nk: int):
    """One (M,N) tile; K is the innermost grid dim, accumulated in o_ref."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ex, sx = ex_ref[...], sx_ref[...]
    ew, sw = ew_ref[...], sw_ref[...]
    zx = (ex == quant.ZERO_CODE)[:, :, None]
    zw = (ew == quant.ZERO_CODE)[None, :, :]
    # INT4 exponent add (masked where either operand is the zero code)
    esum = jnp.where(zx | zw, 0, ex[:, :, None] + ew[None, :, :])
    # 1-bit sign XOR
    ssum = sx[:, :, None] ^ sw[None, :, :]
    mag = _pow2f(esum)
    term = jnp.where(zx | zw, 0.0, jnp.where(ssum == 1, -mag, mag))
    # INT32-accumulator analogue: accumulate signed powers of two
    o_ref[...] += jnp.sum(term, axis=1)
    del nk


def mfmac_pallas(x: jnp.ndarray, w: jnp.ndarray, b: int = 5) -> jnp.ndarray:
    """Full MF-MAC matmul: ALS-PoTQ both operands, log-domain accumulate.

    x: (M, K) f32, w: (K, N) f32 -> (M, N) f32.
    """
    (m, kdim), (_, n) = x.shape, w.shape
    ex, sx, bx, _ = potq_kernel.potq_pallas(x, b)
    ew, sw, bw, _ = potq_kernel.potq_pallas(w, b)

    tm = _TM if m % _TM == 0 else m
    tn = _TN if n % _TN == 0 else n
    tk = _TK if kdim % _TK == 0 else kdim
    grid = (m // tm, n // tn, kdim // tk)
    acc = pl.pallas_call(
        functools.partial(_mfmac_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),
            pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),
            pl.BlockSpec((tk, tn), lambda i, j, k: (k, j)),
            pl.BlockSpec((tk, tn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(ex, sx, ew, sw)
    # the single scalar "shift by beta + beta'" (dequantization)
    return acc * quant.pow2i(bx + bw)


def _mxu_kernel(xq_ref, wq_ref, o_ref):
    """Dequantized-operand schedule: PoT matmul straight onto the MXU."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(xq_ref[...], wq_ref[...])


def mfmac_mxu_pallas(x: jnp.ndarray, w: jnp.ndarray, b: int = 5) -> jnp.ndarray:
    """MF-MAC semantics on the MXU schedule (dequantize, then systolic dot).

    Numerically identical to mfmac_pallas up to f32 accumulation order;
    this is the schedule a current-generation TPU runs to *emulate* the
    proposed MAC, and the one the default training artifacts lower to.
    """
    (m, kdim), (_, n) = x.shape, w.shape
    _, _, bx, xq = potq_kernel.potq_pallas(x, b)
    _, _, bw, wq = potq_kernel.potq_pallas(w, b)
    del bx, bw  # deq values already include 2^beta
    tm = _TM if m % _TM == 0 else m
    tn = _TN if n % _TN == 0 else n
    tk = _TK if kdim % _TK == 0 else kdim
    grid = (m // tm, n // tn, kdim // tk)
    return pl.pallas_call(
        _mxu_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),
            pl.BlockSpec((tk, tn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(xq, wq)


def vmem_footprint_bytes(tm: int = _TM, tn: int = _TN, tk: int = _TK) -> Tuple[int, int]:
    """(log-domain, mxu) VMEM bytes per grid step (perf estimates).

    log-domain: 2 exponent tiles + 2 sign tiles (int8-packed on TPU) +
    f32 accumulator; mxu: 2 f32 operand tiles + f32 accumulator.
    """
    logd = (tm * tk + tk * tn) * 2 + tm * tn * 4
    mxu = (tm * tk + tk * tn) * 4 + tm * tn * 4
    return logd, mxu
