"""L1 perf accounting: VMEM footprint + MXU/VPU utilization *estimates*
per BlockSpec (DESIGN.md §Perf). interpret=True gives CPU-numpy timings
only — not a TPU proxy — so L1 optimization is structural: tile sizes are
chosen against VMEM capacity and MXU shape, and this report quantifies
the choices. Run: cd python && python -m compile.kernels.report
"""

from __future__ import annotations

import dataclasses

from . import mfmac, potq

VMEM_BYTES = 16 * 1024 * 1024  # v4/v5e-class VMEM per core
MXU_SHAPE = 128  # systolic array dimension


@dataclasses.dataclass
class KernelEstimate:
    name: str
    tile: str
    vmem_bytes: int
    vmem_util: float
    notes: str


def estimates() -> list:
    out = []
    # quantizer: row tiles of 256 x N (N = feature dim of typical layers)
    for n in (256, 768, 1024):
        v = potq.vmem_footprint_bytes(4096, n)
        out.append(
            KernelEstimate(
                name=f"potq_quantize n={n}",
                tile=f"256x{n}",
                vmem_bytes=v,
                vmem_util=v / VMEM_BYTES,
                notes="VPU bit-ops only; int8/int1 packing on real HW "
                      "cuts footprint to ~5.1B/elem",
            )
        )
    # mfmac: both schedules at the default 64^3 tiling and an MXU-matched
    # 128^3 tiling
    for tm in (64, 128):
        logd, mxu = mfmac.vmem_footprint_bytes(tm, tm, tm)
        out.append(
            KernelEstimate(
                name=f"mfmac_logdomain tile={tm}",
                tile=f"{tm}x{tm}x{tm}",
                vmem_bytes=logd,
                vmem_util=logd / VMEM_BYTES,
                notes="exponent adds + XOR on VPU; INT32 acc scratch; "
                      "no MXU use (the proposed ASIC path)",
            )
        )
        out.append(
            KernelEstimate(
                name=f"mfmac_mxu tile={tm}",
                tile=f"{tm}x{tm}x{tm}",
                vmem_bytes=mxu,
                vmem_util=mxu / VMEM_BYTES,
                notes=f"dequantized f32 dot on MXU; {tm}/{MXU_SHAPE} of "
                      "systolic dim fed per step"
                      + ("" if tm >= MXU_SHAPE else " (pad waste)"),
            )
        )
    return out


def main() -> None:
    rows = estimates()
    w = max(len(r.name) for r in rows)
    print(f"{'kernel':{w}}  {'tile':>12} {'VMEM':>10} {'util':>7}  notes")
    for r in rows:
        print(
            f"{r.name:{w}}  {r.tile:>12} {r.vmem_bytes/1024:>8.1f}Ki "
            f"{r.vmem_util*100:>6.2f}%  {r.notes}"
        )
    worst = max(rows, key=lambda r: r.vmem_util)
    assert worst.vmem_util < 0.5, "tiles must leave VMEM headroom for double-buffering"
    print(f"\nall tiles < 50% VMEM (worst: {worst.name} at "
          f"{worst.vmem_util*100:.1f}%) — double-buffering headroom OK")


if __name__ == "__main__":
    main()
