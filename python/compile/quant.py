"""Quantization core: ALS-PoTQ (the paper's format) plus baseline formats.

This module is the *numeric contract* shared with the rust mirror
(rust/src/potq). Everything in the PoT path is computed with exact f32 bit
manipulation (no libm log/exp), so the rust implementation can be bit-exact:

  * exponent / mantissa are extracted from the f32 bit pattern;
  * ``round(log2 |x|)`` (paper eq. 2) is ``E + (m > SQRT2_F32)`` where
    ``m in [1, 2)`` is the exact mantissa and ``SQRT2_F32`` is the f32
    nearest sqrt(2) (0x3FB504F3). This matches round-to-nearest in the log
    domain up to <=1 ulp at the rounding boundary (documented deviation);
  * powers of two are constructed from bits, never via ``exp2``.

Terminology follows the paper (Section 4.1):
  b        total PoT bit-width (1 sign + b-1 exponent bits), default 5
  emax     2^(b-2) - 1, the largest exponent magnitude
  alpha    layer-wise scale max|F| / 2^emax          (eq. 7)
  beta     round(log2 alpha), an integer            (eq. 10)
  e        PoT exponent of each element, in [-emax, emax] or ZERO
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

# f32 closest to sqrt(2); the log-domain rounding boundary.
SQRT2_F32 = np.uint32(0x3FB504F3).view(np.float32).item()
# Exponent code meaning "value is zero" in the (e, s) representation.
ZERO_CODE = np.int32(-128)


def pot_emax(b: int) -> int:
    """Largest exponent magnitude representable by a b-bit PoT number."""
    return 2 ** (b - 2) - 1


def _f32_parts(x: jnp.ndarray):
    """Exact sign / biased-exponent / mantissa-value decomposition of f32."""
    bits = lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)
    sign = jnp.right_shift(bits, 31) & 1
    biased = jnp.right_shift(bits, 23) & 0xFF
    m23 = bits & 0x7FFFFF
    # m in [1, 2), exactly representable in f32 (24 significant bits).
    m = 1.0 + m23.astype(jnp.float32) * jnp.float32(2.0**-23)
    return sign, biased, m


def round_log2_abs(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(round(log2|x|), is_zero) with the exact-bit contract above.

    Subnormals and zeros report is_zero=True (flushed). The returned
    exponent for zero entries is ZERO_CODE.
    """
    _, biased, m = _f32_parts(x)
    is_zero = biased == 0
    e = biased - 127 + (m > SQRT2_F32).astype(jnp.int32)
    return jnp.where(is_zero, ZERO_CODE, e), is_zero


def pow2i(e: jnp.ndarray) -> jnp.ndarray:
    """Exact 2^e for integer e in [-126, 127], built from bits."""
    bits = jnp.left_shift((e.astype(jnp.int32) + 127), 23)
    return lax.bitcast_convert_type(bits, jnp.float32)


def compute_beta(f: jnp.ndarray, b: int) -> jnp.ndarray:
    """Layer-wise PoT scale exponent beta = round(log2(max|F| / 2^emax)).

    Returns an int32 scalar; 0 when the block is all-zero.
    """
    amax = jnp.max(jnp.abs(f))
    e, is_zero = round_log2_abs(amax)
    return jnp.where(is_zero, 0, e - pot_emax(b)).astype(jnp.int32)


def pot_quantize(
    f: jnp.ndarray, b: int, beta: Optional[jnp.ndarray] = None
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """ALS-PoTQ: f32 -> (e int32, s int32 in {0,1}, beta int32 scalar).

    e is the *local* exponent in [-emax, emax] (value = (1-2s)*2^(e+beta)),
    or ZERO_CODE for zero. When ``beta`` is None it is computed from the
    block (adaptive layer-wise scaling); passing beta=0 disables ALS.
    """
    emax = pot_emax(b)
    if beta is None:
        beta = compute_beta(f, b)
    sign, biased, m = _f32_parts(f)
    is_zero = biased == 0
    e_real = biased - 127 + (m > SQRT2_F32).astype(jnp.int32)
    e = e_real - beta
    underflow = e < -emax
    e = jnp.minimum(e, emax)
    zero = is_zero | underflow
    e = jnp.where(zero, ZERO_CODE, e)
    s = jnp.where(zero, 0, sign)
    return e.astype(jnp.int32), s.astype(jnp.int32), beta


def pot_dequantize(e: jnp.ndarray, s: jnp.ndarray, beta: jnp.ndarray) -> jnp.ndarray:
    """(e, s, beta) -> f32 value (1-2s) * 2^(e+beta); ZERO_CODE -> 0."""
    zero = e == ZERO_CODE
    mag = pow2i(jnp.where(zero, 0, e + beta))
    val = jnp.where(s == 1, -mag, mag)
    return jnp.where(zero, jnp.float32(0), val)


def pot_value(f: jnp.ndarray, b: int, als: bool = True) -> jnp.ndarray:
    """Round-trip ALS-PoTQ: the dequantized value of f (no gradient logic)."""
    beta = None if als else jnp.int32(0)
    e, s, beta = pot_quantize(f, b, beta)
    return pot_dequantize(e, s, beta)


# ---------------------------------------------------------------------------
# Extensions beyond the paper (ablated in bench ext_ablation):
#  * unbiased stochastic PoT rounding (LUQ-style unbiasedness, PoT grid)
#  * per-channel ALS (beta per output channel instead of per layer)
# ---------------------------------------------------------------------------


def pot_value_unbiased(f: jnp.ndarray, b: int, key) -> jnp.ndarray:
    """Stochastic PoT rounding, unbiased in value: x in [2^k, 2^(k+1))
    rounds up with probability (x - 2^k) / 2^k so E[q(x)] = x inside the
    representable range. Used for gradient quantization ('potu' formats) —
    the bias-free property LUQ argues matters for G.
    """
    emax = pot_emax(b)
    beta = compute_beta(f, b)
    sign, biased, m = _f32_parts(f)
    is_zero = biased == 0
    e_floor = biased - 127  # floor(log2 |f|)
    # round-up probability from the exact mantissa: p = m - 1 in [0, 1)
    p_up = m - 1.0
    u = jax.random.uniform(key, f.shape, jnp.float32)
    e_real = e_floor + (u < p_up).astype(jnp.int32)
    e = e_real - beta
    underflow = e < -emax
    e = jnp.minimum(e, emax)
    zero = is_zero | underflow
    e = jnp.where(zero, ZERO_CODE, e)
    s = jnp.where(zero, 0, sign)
    return pot_dequantize(e.astype(jnp.int32), s.astype(jnp.int32), beta)


def pot_value_per_channel(f: jnp.ndarray, b: int) -> jnp.ndarray:
    """Per-output-channel ALS-PoTQ: one beta per last-axis slice. The
    hardware cost is one extra shift per output channel (still no
    multiplies); ablation of the paper's layer-wise choice."""
    emax = pot_emax(b)
    amax = jnp.max(jnp.abs(f), axis=tuple(range(f.ndim - 1)), keepdims=True)
    e_a, zero_a = round_log2_abs(amax)
    beta = jnp.where(zero_a, 0, e_a - emax)  # (1, ..., C)
    sign, biased, m = _f32_parts(f)
    is_zero = biased == 0
    e_real = biased - 127 + (m > SQRT2_F32).astype(jnp.int32)
    e = e_real - beta
    underflow = e < -emax
    e = jnp.minimum(e, emax)
    zero = is_zero | underflow
    mag = pow2i(jnp.where(zero, 0, e + beta))
    val = jnp.where(sign == 1, -mag, mag)
    return jnp.where(zero, jnp.float32(0), val)


def _value_derived_key(g: jnp.ndarray):
    """Deterministic pseudo-randomness for in-graph stochastic rounding:
    fold the cotangent's bit-content into a PRNG key (the train step has
    no key input; determinism given (state, batch) is a feature)."""
    bits = lax.bitcast_convert_type(g.astype(jnp.float32), jnp.int32)
    seed = jnp.sum(bits.astype(jnp.uint32), dtype=jnp.uint32)
    return jax.random.PRNGKey(seed.astype(jnp.uint32))


# ---------------------------------------------------------------------------
# Baseline formats (used by the comparison schemes only; these are allowed
# to use FP multiplies in quantization — the paper makes the same point
# about S2FP8/LUQ introducing extra multiplications).
# ---------------------------------------------------------------------------


def int_value(f: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Symmetric per-tensor INT-b quantization with an FP scale."""
    qmax = 2.0 ** (bits - 1) - 1
    amax = jnp.max(jnp.abs(f))
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q = jnp.clip(jnp.round(f / scale), -qmax, qmax)
    return q * scale


def fp8_value(f: jnp.ndarray, e_bits: int = 4, m_bits: int = 3) -> jnp.ndarray:
    """S2FP8-style FP8: per-tensor *shifted* e4m3 simulation.

    S2FP8's point is exactly that plain FP8 clips/flushes W/A/G whose
    ranges drift (gradients sit far below 2^-6); the 'shift' moves the
    tensor into FP8's window with a PoT scale, then rounds to e4m3.
    """
    amax = jnp.max(jnp.abs(f))
    # PoT shift placing max|f| near the top of the e4m3 window (448)
    e_shift, shift_zero = round_log2_abs(amax)
    mu = jnp.where(shift_zero, 0, e_shift - 8)  # 2^8 < 448 < 2^9
    scale = pow2i(mu)
    f = f * pow2i(-mu)
    bits = lax.bitcast_convert_type(f.astype(jnp.float32), jnp.int32)
    drop = 23 - m_bits
    # round-to-nearest-even on the dropped mantissa bits
    round_bit = jnp.right_shift(bits, drop) & 1
    bits = bits + ((1 << (drop - 1)) - 1) + round_bit
    bits = jnp.left_shift(jnp.right_shift(bits, drop), drop)
    y = lax.bitcast_convert_type(bits, jnp.float32)
    # clamp exponent range (e4m3: max 448, min normal 2^-6)
    emax_v = jnp.float32(448.0) if e_bits == 4 else jnp.float32(57344.0)
    emin_v = jnp.float32(2.0**-6) if e_bits == 4 else jnp.float32(2.0**-14)
    y = jnp.clip(y, -emax_v, emax_v)
    y = jnp.where(jnp.abs(y) < emin_v, 0.0, y)
    return y * scale


# ---------------------------------------------------------------------------
# Format dispatch + straight-through estimators
# ---------------------------------------------------------------------------

Fmt = Optional[Tuple]  # None | ('pot', b) | ('int', b) | ('fp8',)


def apply_fmt(f: jnp.ndarray, fmt: Fmt, als: bool = True) -> jnp.ndarray:
    """Quantize-dequantize ``f`` according to a format spec (no STE)."""
    if fmt is None:
        return f
    kind = fmt[0]
    if kind == "pot":
        return pot_value(f, fmt[1], als=als)
    if kind == "potu":  # unbiased stochastic PoT (extension)
        return pot_value_unbiased(f, fmt[1], _value_derived_key(f))
    if kind == "potc":  # per-channel ALS (extension)
        return pot_value_per_channel(f, fmt[1])
    if kind == "int":
        return int_value(f, fmt[1])
    if kind == "fp8":
        return fp8_value(f)
    raise ValueError(f"unknown format {fmt!r}")


def ste(f: jnp.ndarray, fmt: Fmt, als: bool = True) -> jnp.ndarray:
    """Straight-through estimator: quantized forward, identity backward."""
    if fmt is None:
        return f
    return f + lax.stop_gradient(apply_fmt(f, fmt, als=als) - f)


# ---------------------------------------------------------------------------
# WBC / PRC (paper sections 4.2, 4.3)
# ---------------------------------------------------------------------------


def weight_bias_correction(w: jnp.ndarray) -> jnp.ndarray:
    """WBC (eq. 11): remove the mean so W matches PoT symmetry."""
    return w - jnp.mean(w)


def ratio_clip(a: jnp.ndarray, gamma: jnp.ndarray) -> jnp.ndarray:
    """PRC (eq. 12): clip at gamma * max|A|.

    The threshold's max|A| factor is treated as a constant (stop_gradient)
    so the gradient w.r.t. gamma is the PACT-style boundary gradient, and
    elements inside the range get a pass-through gradient.
    """
    t = lax.stop_gradient(jnp.max(jnp.abs(a))) * gamma
    return jnp.clip(a, -t, t)


# ---------------------------------------------------------------------------
# Gradient quantization (Algorithm 1, lines 13-15): an identity-forward op
# whose backward pass runs the cotangent through ALS-PoTQ, so the two
# backward matmuls consume quantized G.
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def grad_quant(y: jnp.ndarray, fmt: Fmt, als: bool = True) -> jnp.ndarray:
    return y


def _gq_fwd(y, fmt, als):
    return y, None


def _gq_bwd(fmt, als, _res, g):
    return (apply_fmt(g, fmt, als=als),)


grad_quant.defvjp(_gq_fwd, _gq_bwd)


# ---------------------------------------------------------------------------
# Scheme registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Scheme:
    """A full training quantization scheme (what Table 2's rows are)."""

    name: str
    w: Fmt = None
    a: Fmt = None
    g: Fmt = None
    g_last: Fmt = None  # format for the last layer's gradient (Appendix D)
    wbc: bool = False
    prc: bool = False
    als: bool = True
    gamma_init: float = 0.9
    gamma_decay: float = 1e-3  # L2 pull on gamma (PACT-style regularizer)

    @property
    def quantized(self) -> bool:
        return self.w is not None or self.a is not None or self.g is not None


SCHEMES = {
    # full-precision baseline
    "fp32": Scheme("fp32"),
    # ours: the paper's complete multiplication-free scheme
    "mf": Scheme(
        "mf", w=("pot", 5), a=("pot", 5), g=("pot", 5), g_last=("pot", 6),
        wbc=True, prc=True, als=True,
    ),
    # ablations (Table 5)
    "mf_nowbc": Scheme(
        "mf_nowbc", w=("pot", 5), a=("pot", 5), g=("pot", 5), g_last=("pot", 6),
        wbc=False, prc=True, als=True,
    ),
    "mf_noprc": Scheme(
        "mf_noprc", w=("pot", 5), a=("pot", 5), g=("pot", 5), g_last=("pot", 6),
        wbc=True, prc=False, als=True,
    ),
    "mf_noals": Scheme(
        "mf_noals", w=("pot", 5), a=("pot", 5), g=("pot", 5), g_last=("pot", 6),
        wbc=True, prc=True, als=False,
    ),
    # baselines (Tables 2-4): closest from-scratch analogues
    "wpot5": Scheme("wpot5", w=("pot", 5)),  # DeepShift-like (W-only PoT5)
    "wapot4": Scheme("wapot4", w=("pot", 4), a=("pot", 4)),  # LogNN-like
    "luq4": Scheme("luq4", w=("int", 4), a=("int", 4), g=("pot", 5)),  # LUQ-like
    "fp8": Scheme("fp8", w=("fp8",), a=("fp8",), g=("fp8",)),  # S2FP8-like
    "int8": Scheme("int8", w=("int", 8), a=("int", 8), g=("int", 8)),
    # bit-width sweep (the b=5 design-choice ablation; 4-bit keeps an
    # emax of 3, 6-bit widens to 15)
    "mf4": Scheme(
        "mf4", w=("pot", 4), a=("pot", 4), g=("pot", 4), g_last=("pot", 5),
        wbc=True, prc=True, als=True,
    ),
    "mf6": Scheme(
        "mf6", w=("pot", 6), a=("pot", 6), g=("pot", 6), g_last=("pot", 6),
        wbc=True, prc=True, als=True,
    ),
    # extensions beyond the paper (bench ext_ablation)
    "mf_sr": Scheme(  # unbiased stochastic PoT rounding for G
        "mf_sr", w=("pot", 5), a=("pot", 5), g=("potu", 5), g_last=("potu", 6),
        wbc=True, prc=True, als=True,
    ),
    "mf_pc": Scheme(  # per-channel ALS for W
        "mf_pc", w=("potc", 5), a=("pot", 5), g=("pot", 5), g_last=("pot", 6),
        wbc=True, prc=True, als=True,
    ),
}


def get_scheme(name: str) -> Scheme:
    try:
        return SCHEMES[name]
    except KeyError:
        raise KeyError(f"unknown scheme {name!r}; have {sorted(SCHEMES)}")
