"""Training-step construction and state-vector packing (Layer-2 glue).

The entire mutable training state — parameters, SGD momentum, BN running
stats, and a couple of scalar extras (last loss, step counter) — is packed
into ONE flat f32 vector. The rust coordinator holds that vector as a
device-resident PJRT buffer and feeds it back into ``train_step`` every
iteration with zero host copies; scalar metrics are read back through the
tiny ``slice_metrics`` executable (see DESIGN.md, runtime decisions).

Exported step functions (all pure, all lowered AOT by aot.py):
  init(seed)                  -> state                      f32[S]
  train_step(state, x, y, lr) -> state'                     f32[S]
  eval_step(state, x, y)      -> [sum_loss, n_correct]      f32[2]
  probe(state, x, y)          -> [W_l | A_l | G_l] raveled  f32[K]
  slice_metrics(state)        -> [loss, step]               f32[2]
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from . import models as model_zoo
from .quant import Scheme, get_scheme

MOMENTUM = 0.9  # SGD momentum, Appendix D


@dataclasses.dataclass
class Built:
    """Everything aot.py needs for one (model, scheme, batch) variant."""

    name: str
    model: Any
    cfg: Any
    scheme: Scheme
    batch: int
    use_pallas: bool
    weight_decay: float
    fns: Dict[str, Callable]
    example_args: Dict[str, Tuple]
    manifest: Dict[str, Any]


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def _layout(tree) -> Tuple[list, int]:
    """(entries, total): offsets of every leaf in ravel_pytree order."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    entries, off = [], 0
    for path, leaf in leaves:
        n = int(leaf.size)
        entries.append(
            {
                "path": _path_str(path),
                "offset": off,
                "size": n,
                "shape": list(leaf.shape),
            }
        )
        off += n
    return entries, off


def _decay_for(path_str: str, weight_decay: float, scheme: Scheme) -> float:
    leaf = path_str.rsplit("/", 1)[-1]
    if leaf == "w" and "emb" not in path_str:
        return weight_decay
    if leaf == "gamma":
        return scheme.gamma_decay
    return 0.0


def build(
    name: str,
    model_name: str,
    cfg: Any,
    scheme_name: str,
    batch: int,
    use_pallas: bool = False,
    weight_decay: float = 5e-4,
    seed: int = 0,
) -> Built:
    model = model_zoo.get(model_name)
    scheme = get_scheme(scheme_name)

    # Template state (shapes only — aot lowers functions, never runs them;
    # the template is also what defines the layout manifest).
    params0, stats0 = model.init(jax.random.PRNGKey(seed), cfg, scheme)
    template = {
        "p": params0,
        "m": jax.tree_util.tree_map(jnp.zeros_like, params0),
        "s": stats0,
        "x": {"loss": jnp.float32(0), "step": jnp.float32(0)},
    }
    flat0, unravel = ravel_pytree(template)
    state_len = int(flat0.size)
    entries, total = _layout(template)
    assert total == state_len, "layout does not match ravel order"

    (x_shape, x_dtype), (y_shape, y_dtype) = model.input_spec(cfg, batch)

    # ---- step functions -------------------------------------------------
    def init(seed_arr):
        key = jax.random.PRNGKey(seed_arr)
        p, s = model.init(key, cfg, scheme)
        tree = {
            "p": p,
            "m": jax.tree_util.tree_map(jnp.zeros_like, p),
            "s": s,
            "x": {"loss": jnp.float32(0), "step": jnp.float32(0)},
        }
        return ravel_pytree(tree)[0]

    def train_step(state, x, y, lr):
        st = unravel(state)

        def loss_fn(p):
            logits, new_stats, _ = model.apply(p, st["s"], x, scheme, True,
                                               use_pallas=use_pallas)
            sum_ce, _, n = model.loss_and_correct(logits, y)
            return sum_ce / n, new_stats

        (loss, new_stats), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            st["p"]
        )

        def upd(path, m, g, p):
            wd = _decay_for(_path_str(path), weight_decay, scheme)
            return MOMENTUM * m + g + wd * p

        m = jax.tree_util.tree_map_with_path(upd, st["m"], grads, st["p"])
        p = jax.tree_util.tree_map(lambda p_, m_: p_ - lr * m_, st["p"], m)
        out = {
            "p": p,
            "m": m,
            "s": new_stats,
            "x": {"loss": loss, "step": st["x"]["step"] + 1},
        }
        return ravel_pytree(out)[0]

    def eval_step(state, x, y):
        st = unravel(state)
        logits, _, _ = model.apply(st["p"], st["s"], x, scheme, False,
                                   use_pallas=use_pallas)
        sum_ce, correct, _ = model.loss_and_correct(logits, y)
        return jnp.stack([sum_ce, correct])

    tap_shape = model.tap_shape(cfg, batch)
    wpath = model.tap_weight_path(cfg)

    def probe(state, x, y):
        st = unravel(state)

        def f(z):
            logits, _, aux = model.apply(st["p"], st["s"], x, scheme, True,
                                         tap_z=z, use_pallas=use_pallas)
            sum_ce, _, n = model.loss_and_correct(logits, y)
            return sum_ce / n, aux["tap_a"]

        g, a = jax.grad(f, has_aux=True)(jnp.zeros(tap_shape, jnp.float32))
        w = st["p"]
        for k in wpath:
            w = w[k]
        return jnp.concatenate([w.ravel(), a.ravel(), g.ravel()])

    def slice_metrics(state):
        st = unravel(state)
        return jnp.stack([st["x"]["loss"], st["x"]["step"]])

    # ---- example args for lowering --------------------------------------
    f32, i32 = jnp.float32, jnp.int32
    sds = jax.ShapeDtypeStruct
    ex = {
        "init": (sds((), i32),),
        "train": (sds((state_len,), f32), sds(x_shape, x_dtype),
                  sds(y_shape, y_dtype), sds((), f32)),
        "eval": (sds((state_len,), f32), sds(x_shape, x_dtype),
                 sds(y_shape, y_dtype)),
        "probe": (sds((state_len,), f32), sds(x_shape, x_dtype),
                  sds(y_shape, y_dtype)),
        "slice": (sds((state_len,), f32),),
    }

    # ---- manifest --------------------------------------------------------
    n_w = int(w_size(params0, wpath))
    n_a = 1
    for d in tap_shape:
        n_a *= d
    n_params = sum(
        int(l.size) for l in jax.tree_util.tree_leaves(params0)
    )
    manifest = {
        "name": name,
        "model": model_name,
        "scheme": scheme_name,
        "batch": batch,
        "use_pallas": use_pallas,
        "state_len": state_len,
        "n_params": n_params,
        "weight_decay": weight_decay,
        "momentum": MOMENTUM,
        "inputs": {
            "x": {"shape": list(x_shape), "dtype": str(jnp.dtype(x_dtype).name)},
            "y": {"shape": list(y_shape), "dtype": str(jnp.dtype(y_dtype).name)},
        },
        "layout": entries,
        "loss_offset": _find(entries, "x/loss"),
        "step_offset": _find(entries, "x/step"),
        "eval_outputs": ["sum_loss", "n_correct"],
        "eval_denom": _eval_denom(model_name, cfg, batch),
        "probe": {
            "weight_path": "/".join(wpath),
            "sections": [
                {"name": "w", "offset": 0, "size": n_w},
                {"name": "a", "offset": n_w, "size": n_a},
                {"name": "g", "offset": n_w + n_a, "size": n_a},
            ],
        },
        "model_cfg": dataclasses.asdict(cfg),
    }

    fns = {"init": init, "train": train_step, "eval": eval_step,
           "probe": probe, "slice": slice_metrics}
    return Built(name, model, cfg, scheme, batch, use_pallas, weight_decay,
                 fns, ex, manifest)


def w_size(params, wpath) -> int:
    w = params
    for k in wpath:
        w = w[k]
    return int(w.size)


def _find(entries, path: str) -> int:
    for e in entries:
        if e["path"] == path:
            return e["offset"]
    raise KeyError(path)


def _eval_denom(model_name: str, cfg, batch: int) -> int:
    if model_name == "transformer":
        return batch * cfg.seq
    return batch
