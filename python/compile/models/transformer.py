"""Small Transformer for sequence transduction (the WMT En-De stand-in).

Full-attention encoder with a per-position classification head; the task
(rust/src/data/seq.rs) is deterministic transduction: y[t] = (x[S-1-t] +
shift) mod vocab — reversal plus shift, which requires genuine long-range
attention. All projection / FFN / head layers are quantized linears
(Algorithm 1); attention scores, softmax and norms stay FP32, matching the
paper's scope (linear layers only).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .. import layers
from ..quant import Scheme


@dataclasses.dataclass(frozen=True)
class Cfg:
    vocab: int = 64
    seq: int = 32
    d: int = 96
    heads: int = 4
    ffn: int = 192
    depth: int = 2


def init(key, cfg: Cfg, scheme: Scheme):
    params = {}
    key, k1, k2 = jax.random.split(key, 3)
    params["tok_emb"] = {"w": jax.random.normal(k1, (cfg.vocab, cfg.d)) * 0.02}
    params["pos_emb"] = {"w": jax.random.normal(k2, (cfg.seq, cfg.d)) * 0.02}
    for i in range(cfg.depth):
        key, kq, kk, kv, ko, k5, k6 = jax.random.split(key, 7)
        params[f"l{i}_q"] = layers.dense_init(kq, cfg.d, cfg.d, scheme)
        params[f"l{i}_k"] = layers.dense_init(kk, cfg.d, cfg.d, scheme)
        params[f"l{i}_v"] = layers.dense_init(kv, cfg.d, cfg.d, scheme)
        params[f"l{i}_o"] = layers.dense_init(ko, cfg.d, cfg.d, scheme)
        params[f"l{i}_f1"] = layers.dense_init(k5, cfg.d, cfg.ffn, scheme)
        params[f"l{i}_f2"] = layers.dense_init(k6, cfg.ffn, cfg.d, scheme)
        params[f"l{i}_ln1"] = layers.ln_init(cfg.d)
        params[f"l{i}_ln2"] = layers.ln_init(cfg.d)
    key, kh = jax.random.split(key)
    params["ln_f"] = layers.ln_init(cfg.d)
    params["head"] = layers.dense_init(kh, cfg.d, cfg.vocab, scheme)
    return params, {}


def _attention(params, h, cfg: Cfg, scheme: Scheme, i: int, use_pallas: bool):
    b, s, d = h.shape
    hd = d // cfg.heads
    q = layers.qdense(params[f"l{i}_q"], h, scheme, use_pallas=use_pallas)
    k = layers.qdense(params[f"l{i}_k"], h, scheme, use_pallas=use_pallas)
    v = layers.qdense(params[f"l{i}_v"], h, scheme, use_pallas=use_pallas)
    q = q.reshape(b, s, cfg.heads, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, cfg.heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.heads, hd).transpose(0, 2, 1, 3)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(hd))
    att = jax.nn.softmax(att, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, d)
    return layers.qdense(params[f"l{i}_o"], o, scheme, use_pallas=use_pallas)


def apply(params, stats, x, scheme: Scheme, train: bool,
          tap_z: Optional[jnp.ndarray] = None, use_pallas: bool = False):
    del train
    cfg = _cfg_from(params)
    h = params["tok_emb"]["w"][x] + params["pos_emb"]["w"][None, :, :]
    aux = {}
    for i in range(cfg.depth):
        if i == 1 or (cfg.depth == 1 and i == 0):  # canonical probe layer
            if tap_z is not None:
                h = h + tap_z
            aux["tap_a"] = h
        hn = layers.layernorm(params[f"l{i}_ln1"], h)
        h = h + _attention(params, hn, cfg, scheme, i, use_pallas)
        hn = layers.layernorm(params[f"l{i}_ln2"], h)
        f = layers.qdense(params[f"l{i}_f1"], hn, scheme, use_pallas=use_pallas)
        f = jax.nn.relu(f)
        f = layers.qdense(params[f"l{i}_f2"], f, scheme, use_pallas=use_pallas)
        h = h + f
    h = layers.layernorm(params["ln_f"], h)
    logits = layers.qdense(params["head"], h, scheme, last=True,
                           use_pallas=use_pallas)
    return logits, stats, aux


def _cfg_from(params) -> Cfg:
    vocab, d = params["tok_emb"]["w"].shape
    seq = params["pos_emb"]["w"].shape[0]
    ffn = params["l0_f1"]["w"].shape[1]
    depth = len([k for k in params if k.endswith("_f1")])
    return Cfg(vocab=vocab, seq=seq, d=d, heads=4, ffn=ffn, depth=depth)


def tap_shape(cfg: Cfg, batch: int):
    return (batch, cfg.seq, cfg.d)


def tap_weight_path(cfg: Cfg):
    i = 1 if cfg.depth > 1 else 0
    return (f"l{i}_q", "w")


def input_spec(cfg: Cfg, batch: int):
    return ((batch, cfg.seq), jnp.int32), ((batch, cfg.seq), jnp.int32)


def loss_and_correct(logits, y):
    ce = layers.softmax_xent(logits, y)
    correct = jnp.sum((jnp.argmax(logits, -1) == y).astype(jnp.float32))
    return jnp.sum(ce), correct, ce.shape[0] * ce.shape[1]
