"""MLP classifier (AlexNet stand-in at toy scale; also the quickstart model)."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .. import layers
from ..quant import Scheme


@dataclasses.dataclass(frozen=True)
class Cfg:
    in_dim: int = 768  # 16x16x3 flattened
    hidden: Tuple[int, ...] = (256, 128)
    classes: int = 10


def init(key, cfg: Cfg, scheme: Scheme):
    dims = (cfg.in_dim, *cfg.hidden, cfg.classes)
    params = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        key, sub = jax.random.split(key)
        params[f"fc{i}"] = layers.dense_init(sub, a, b, scheme)
    return params, {}


def apply(params, stats, x, scheme: Scheme, train: bool,
          tap_z: Optional[jnp.ndarray] = None, use_pallas: bool = False):
    del train
    h = x.reshape(x.shape[0], -1)
    n = len(params)
    aux = {}
    for i in range(n):
        if i == 1:  # canonical probe layer: input of fc1
            if tap_z is not None:
                h = h + tap_z
            aux["tap_a"] = h
        h = layers.qdense(params[f"fc{i}"], h, scheme,
                          last=(i == n - 1), use_pallas=use_pallas)
        if i < n - 1:
            h = jax.nn.relu(h)
    return h, stats, aux


def tap_shape(cfg: Cfg, batch: int):
    return (batch, cfg.hidden[0])


def tap_weight_path(cfg: Cfg):
    return ("fc1", "w")


def input_spec(cfg: Cfg, batch: int):
    return ((batch, cfg.in_dim), jnp.float32), ((batch,), jnp.int32)


def loss_and_correct(logits, y):
    ce = layers.softmax_xent(logits, y)
    correct = jnp.sum((jnp.argmax(logits, -1) == y).astype(jnp.float32))
    return jnp.sum(ce), correct, ce.shape[0]
