"""Model zoo (Layer-2). Each model module exposes:

  Cfg                      dataclass of hyperparameters
  init(key, cfg, scheme)   -> (params, stats) pytrees
  apply(params, stats, x, scheme, train, tap_z=None, use_pallas=False)
                           -> (logits, new_stats, aux) where aux['tap_a'] is
                              the canonical probe activation (input of the
                              designated quantized layer)
  tap_shape(cfg, batch)    static shape of that activation
  tap_weight_path(cfg)     params path (tuple of keys) of the probed weight
  input_spec(cfg, batch)   ((x_shape, x_dtype), (y_shape, y_dtype))
  loss_and_correct(logits, y) -> (per-batch summed CE, # correct)
"""

from . import cnn, mlp, transformer  # noqa: F401

MODELS = {"mlp": mlp, "cnn": cnn, "cnn_deep": cnn, "transformer": transformer}


def get(name: str):
    return MODELS[name]
