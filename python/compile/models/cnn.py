"""Mini-ResNet (He et al. CIFAR-style) — the ResNet18/50/101 stand-in.

depth = 6n+2-style: ``blocks`` residual blocks per stage, 3 stages,
widths (w, 2w, 4w), strides (1, 2, 2). ``blocks=2`` ~ ResNet-14 (the
ResNet18/50 stand-in), ``blocks=3`` ~ ResNet-20 (the ResNet101 stand-in,
Table 6).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .. import layers
from ..quant import Scheme


@dataclasses.dataclass(frozen=True)
class Cfg:
    size: int = 16      # input is size x size x 3
    width: int = 8      # stem width w; stages are (w, 2w, 4w)
    blocks: int = 2     # residual blocks per stage
    classes: int = 10


def _widths(cfg: Cfg):
    return (cfg.width, cfg.width * 2, cfg.width * 4)


def init(key, cfg: Cfg, scheme: Scheme):
    params, stats = {}, {}
    key, sub = jax.random.split(key)
    params["stem"] = layers.conv_init(sub, 3, 3, 3, cfg.width, scheme)
    params["stem_bn"], stats["stem_bn"] = layers.bn_init(cfg.width)
    cin = cfg.width
    for s, w in enumerate(_widths(cfg)):
        for j in range(cfg.blocks):
            name = f"s{s}b{j}"
            key, k1, k2, k3 = jax.random.split(key, 4)
            params[f"{name}_c1"] = layers.conv_init(k1, 3, 3, cin, w, scheme)
            params[f"{name}_bn1"], stats[f"{name}_bn1"] = layers.bn_init(w)
            params[f"{name}_c2"] = layers.conv_init(k2, 3, 3, w, w, scheme)
            params[f"{name}_bn2"], stats[f"{name}_bn2"] = layers.bn_init(w)
            if cin != w:
                params[f"{name}_proj"] = layers.conv_init(k3, 1, 1, cin, w, scheme)
            cin = w
    key, sub = jax.random.split(key)
    params["head"] = layers.dense_init(sub, cin, cfg.classes, scheme)
    return params, stats


def apply(params, stats, x, scheme: Scheme, train: bool,
          tap_z: Optional[jnp.ndarray] = None, use_pallas: bool = False):
    del use_pallas  # conv path has no pallas variant (see DESIGN.md)
    new_stats = {}
    h = layers.qconv(params["stem"], x, scheme)
    h, new_stats["stem_bn"] = layers.batchnorm(
        params["stem_bn"], stats["stem_bn"], h, train)
    h = jax.nn.relu(h)
    aux = {}
    for s in range(3):
        stride = 1 if s == 0 else 2
        for j in range(_n_blocks(params, s)):
            name = f"s{s}b{j}"
            st = stride if j == 0 else 1
            if s == 1 and j == 0:  # canonical probe layer: stage-1 entry
                if tap_z is not None:
                    h = h + tap_z
                aux["tap_a"] = h
            skip = h
            o = layers.qconv(params[f"{name}_c1"], h, scheme, stride=st)
            o, new_stats[f"{name}_bn1"] = layers.batchnorm(
                params[f"{name}_bn1"], stats[f"{name}_bn1"], o, train)
            o = jax.nn.relu(o)
            o = layers.qconv(params[f"{name}_c2"], o, scheme)
            o, new_stats[f"{name}_bn2"] = layers.batchnorm(
                params[f"{name}_bn2"], stats[f"{name}_bn2"], o, train)
            if f"{name}_proj" in params:
                skip = layers.qconv(params[f"{name}_proj"], skip, scheme, stride=st)
            elif st != 1:
                skip = skip[:, ::st, ::st, :]
            h = jax.nn.relu(o + skip)
    h = jnp.mean(h, axis=(1, 2))
    logits = layers.qdense(params["head"], h, scheme, last=True)
    return logits, new_stats, aux


def _n_blocks(params, stage: int) -> int:
    return len([k for k in params if k.startswith(f"s{stage}b") and k.endswith("_c1")])


def tap_shape(cfg: Cfg, batch: int):
    return (batch, cfg.size, cfg.size, cfg.width)


def tap_weight_path(cfg: Cfg):
    return ("s1b0_c1", "w")


def input_spec(cfg: Cfg, batch: int):
    return ((batch, cfg.size, cfg.size, 3), jnp.float32), ((batch,), jnp.int32)


def loss_and_correct(logits, y):
    ce = layers.softmax_xent(logits, y)
    correct = jnp.sum((jnp.argmax(logits, -1) == y).astype(jnp.float32))
    return jnp.sum(ce), correct, ce.shape[0]
